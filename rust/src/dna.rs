//! Character encoding for pattern matching (paper §3.1).
//!
//! CRAM-PM stores strings with a fixed-width binary code — 2 bits per
//! character for the DNA alphabet {A, C, G, T}, and wider codes for
//! the text benchmarks (see [`crate::alphabet`] for the width-generic
//! machinery). One character-level comparison therefore costs
//! `bits_per_char` bit-level XORs plus one NOR-reduction (§3.2).

use crate::alphabet::{Alphabet, PackedSeq};

/// The four DNA bases in code order: `A=00, C=01, G=10, T=11`.
pub const BASES: [u8; 4] = [b'A', b'C', b'G', b'T'];

/// Encode one base to its 2-bit code. Panics on non-ACGT input.
pub fn encode_base(b: u8) -> u8 {
    match b {
        b'A' | b'a' => 0,
        b'C' | b'c' => 1,
        b'G' | b'g' => 2,
        b'T' | b't' => 3,
        _ => panic!("not a DNA base: {:?}", b as char),
    }
}

/// Decode a 2-bit code back to its base character.
pub fn decode_base(code: u8) -> u8 {
    BASES[(code & 0b11) as usize]
}

/// Encode an ACGT string into 2-bit codes, one code per byte.
pub fn encode(seq: &[u8]) -> Vec<u8> {
    seq.iter().map(|&b| encode_base(b)).collect()
}

/// Decode 2-bit codes back into an ACGT string.
pub fn decode(codes: &[u8]) -> Vec<u8> {
    codes.iter().map(|&c| decode_base(c)).collect()
}

/// A string of 2-bit codes together with its bit-level view — the form
/// in which data lives in a CRAM-PM row compartment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Encoded {
    /// One 2-bit code per character.
    pub codes: Vec<u8>,
}

impl Encoded {
    /// Encode an ACGT byte string.
    pub fn from_ascii(seq: &[u8]) -> Self {
        Encoded { codes: encode(seq) }
    }

    /// Character length.
    pub fn len(&self) -> usize {
        self.codes.len()
    }

    /// Whether the string is empty.
    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Bit-level view, LSB-first per character: character `i` occupies
    /// bits `2i` (low) and `2i + 1` (high) — the column order used by
    /// the array layout (§3.1).
    pub fn bits(&self) -> Vec<bool> {
        let mut out = Vec::with_capacity(self.codes.len() * 2);
        for &c in &self.codes {
            out.push(c & 1 == 1);
            out.push(c & 2 == 2);
        }
        out
    }

    /// Rebuild from the bit-level view produced by [`Encoded::bits`].
    pub fn from_bits(bits: &[bool]) -> Self {
        assert!(bits.len() % 2 == 0, "bit string must pair up into 2-bit codes");
        let codes = bits
            .chunks(2)
            .map(|pair| pair[0] as u8 | (pair[1] as u8) << 1)
            .collect();
        Encoded { codes }
    }
}

/// A 2-bit-packed sequence: 32 characters per `u64` word, character
/// `i` at bits `2i..2i+2` (LSB-first — the same order as
/// [`Encoded::bits`] and the array layout).
///
/// §Perf: this is the host-side mirror of the substrate's word
/// parallelism — one XOR + popcount step compares 32 characters, so
/// the CPU oracle scores an alignment in `⌈pat/32⌉` word ops instead
/// of a per-character loop. Since the alphabet generalization it is a
/// thin DNA-width wrapper over [`crate::alphabet::PackedSeq`], so the
/// 2-bit path and the width-generic path are one implementation.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Packed2(PackedSeq);

impl Packed2 {
    /// Pack a string of 2-bit codes (one code per byte).
    pub fn from_codes(codes: &[u8]) -> Self {
        Packed2(PackedSeq::from_codes(Alphabet::Dna2, codes))
    }

    /// Re-pack in place, reusing the word buffer — the scratch path for
    /// callers that pack many sequences back to back (one heap
    /// allocation amortized over all of them).
    pub fn refill(&mut self, codes: &[u8]) {
        self.0.refill(Alphabet::Dna2, codes);
    }

    /// Character length.
    pub fn chars(&self) -> usize {
        self.0.chars()
    }

    /// The underlying width-generic packed sequence.
    pub fn as_seq(&self) -> &PackedSeq {
        &self.0
    }
}

/// Word-parallel similarity: the number of matching characters between
/// `pattern` and the `fragment` window at alignment `loc`, 32
/// characters per XOR+popcount step. Exactly equals [`similarity`] on
/// the unpacked codes (see [`crate::alphabet::packed_similarity`]).
pub fn packed_similarity(fragment: &Packed2, pattern: &Packed2, loc: usize) -> usize {
    crate::alphabet::packed_similarity(&fragment.0, &pattern.0, loc)
}

/// Best `(score, loc)` of `pattern` against `fragment` under the
/// row-major tie-break (strict `>`, so the lowest `loc` wins a tie) —
/// the packed, allocation-free replacement for scanning
/// [`score_profile`]. `None` iff the pattern is empty or longer than
/// the fragment (no alignments).
pub fn packed_best_alignment(fragment: &Packed2, pattern: &Packed2) -> Option<(usize, usize)> {
    crate::alphabet::packed_best_alignment(&fragment.0, &pattern.0)
}

/// Similarity score between a pattern and a reference window at a given
/// alignment: the number of matching characters (§3, "similarity
/// score"). This is the scalar oracle every other engine (bit-level
/// array, XLA artifact, step model) is validated against.
pub fn similarity(reference: &[u8], pattern: &[u8], loc: usize) -> usize {
    assert!(loc + pattern.len() <= reference.len(), "alignment out of range");
    reference[loc..loc + pattern.len()]
        .iter()
        .zip(pattern)
        .filter(|(a, b)| a == b)
        .count()
}

/// All similarity scores of `pattern` against `fragment` — one per
/// alignment `loc` per Algorithm 1.
pub fn score_profile(fragment: &[u8], pattern: &[u8]) -> Vec<usize> {
    if pattern.is_empty() || pattern.len() > fragment.len() {
        return Vec::new();
    }
    (0..=fragment.len() - pattern.len())
        .map(|loc| similarity(fragment, pattern, loc))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_encode_decode() {
        let s = b"ACGTACGTTTGGCCAA";
        assert_eq!(decode(&encode(s)), s.to_vec());
    }

    #[test]
    fn bit_view_roundtrip() {
        let e = Encoded::from_ascii(b"GATTACA");
        assert_eq!(Encoded::from_bits(&e.bits()), e);
        assert_eq!(e.bits().len(), 14);
    }

    #[test]
    fn bit_order_lsb_first() {
        // G = 10₂ → low bit 0, high bit 1.
        let e = Encoded::from_ascii(b"G");
        assert_eq!(e.bits(), vec![false, true]);
    }

    #[test]
    fn similarity_counts_matches() {
        let reference = encode(b"ACGTACGT");
        let pattern = encode(b"ACGT");
        assert_eq!(similarity(&reference, &pattern, 0), 4);
        assert_eq!(similarity(&reference, &pattern, 4), 4);
        assert_eq!(similarity(&reference, &pattern, 1), 0); // CGTA vs ACGT
        assert_eq!(similarity(&reference, &pattern, 2), 0); // GTAC vs ACGT
    }

    #[test]
    fn score_profile_length() {
        let fragment = encode(b"ACGTACGTAC");
        let pattern = encode(b"ACGT");
        let profile = score_profile(&fragment, &pattern);
        assert_eq!(profile.len(), 7);
        assert_eq!(profile[0], 4);
    }

    #[test]
    #[should_panic(expected = "not a DNA base")]
    fn rejects_non_dna() {
        encode(b"ACGN");
    }

    #[test]
    fn packed_similarity_equals_scalar_across_boundaries() {
        // Lengths straddling the 32-char word boundary and windows at
        // every offset: the packed scorer must equal the scalar oracle.
        let mut rng = crate::util::Rng::new(0x2B17);
        for (frag_len, pat_len) in [(7, 3), (32, 32), (33, 17), (64, 33), (100, 64), (130, 5)] {
            let frag = encode(&rng.dna(frag_len));
            let pat = encode(&rng.dna(pat_len));
            let pf = Packed2::from_codes(&frag);
            let pp = Packed2::from_codes(&pat);
            assert_eq!(pf.chars(), frag_len);
            for loc in 0..=frag_len - pat_len {
                assert_eq!(
                    packed_similarity(&pf, &pp, loc),
                    similarity(&frag, &pat, loc),
                    "frag={frag_len} pat={pat_len} loc={loc}"
                );
            }
        }
    }

    #[test]
    fn packed_best_alignment_matches_profile_scan() {
        let mut rng = crate::util::Rng::new(0xBE57);
        for _ in 0..50 {
            let frag_len = 1 + rng.below(90);
            let pat_len = 1 + rng.below(frag_len);
            let frag = encode(&rng.dna(frag_len));
            let pat = encode(&rng.dna(pat_len));
            // The scan the CPU engine used to do: strict > over the
            // profile keeps the lowest loc.
            let mut want: Option<(usize, usize)> = None;
            for (loc, &s) in score_profile(&frag, &pat).iter().enumerate() {
                if want.map_or(true, |(bs, _)| s > bs) {
                    want = Some((s, loc));
                }
            }
            let got =
                packed_best_alignment(&Packed2::from_codes(&frag), &Packed2::from_codes(&pat));
            assert_eq!(got, want, "frag={frag_len} pat={pat_len}");
        }
    }

    #[test]
    fn packed_best_alignment_empty_cases() {
        let frag = Packed2::from_codes(&encode(b"ACGT"));
        let empty = Packed2::from_codes(&[]);
        assert_eq!(packed_best_alignment(&frag, &empty), None);
        let long = Packed2::from_codes(&encode(b"ACGTA"));
        assert_eq!(packed_best_alignment(&frag, &long), None);
    }
}

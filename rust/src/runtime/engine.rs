//! The PJRT execution engine: HLO text → compiled executables →
//! score computation on the hot path.

use crate::runtime::{Manifest, Variant};
use crate::util::FxHashMap;
use crate::Result;
use anyhow::{anyhow, Context};
use std::path::Path;

// The offline build image vendors no PJRT crate; `xla_stub` mirrors the
// API slice used below. Point this alias at the real `xla` crate to
// re-enable the PJRT hot path.
use crate::runtime::xla_stub as xla;

/// Output of one executable invocation (one array pass).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PassOutput {
    /// Row-major scores, `rows × n_alignments`.
    pub scores: Vec<i32>,
    /// Per-row best alignment offset.
    pub best_loc: Vec<i32>,
    /// Per-row best score.
    pub best_score: Vec<i32>,
    /// Alignments per row (the score row stride).
    pub n_alignments: usize,
}

impl PassOutput {
    /// Score of `row` at alignment `loc`.
    pub fn score(&self, row: usize, loc: usize) -> i32 {
        self.scores[row * self.n_alignments + loc]
    }
}

struct LoadedVariant {
    variant: Variant,
    exe: xla::PjRtLoadedExecutable,
}

/// The runtime: a PJRT CPU client plus one compiled executable per
/// manifest variant.
pub struct Runtime {
    client: xla::PjRtClient,
    variants: FxHashMap<String, LoadedVariant>,
}

impl Runtime {
    /// Load every artifact in `dir` and compile it on the CPU client.
    ///
    /// HLO **text** is the interchange format (see `aot.py`): the text
    /// parser reassigns instruction ids, sidestepping the 64-bit-id
    /// protos jax ≥ 0.5 emits that xla_extension 0.5.1 rejects.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        let mut variants = FxHashMap::default();
        for v in &manifest.variants {
            let path = manifest.hlo_path(v);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not UTF-8")?,
            )
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e}", v.name))?;
            variants.insert(v.name.clone(), LoadedVariant { variant: v.clone(), exe });
        }
        Ok(Runtime { client, variants })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of the loaded variants.
    pub fn variant_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.variants.keys().map(|s| s.as_str()).collect();
        names.sort_unstable();
        names
    }

    /// Shape metadata of a variant.
    pub fn variant(&self, name: &str) -> Option<&Variant> {
        self.variants.get(name).map(|lv| &lv.variant)
    }

    /// Execute one array pass: `frag_codes` is row-major
    /// `rows × frag_chars` (2-bit codes as i32), `pat_codes` is
    /// `pat_chars` long. Shorter inputs are zero-padded to the
    /// variant's shape ('A'-padding; callers mask padded rows).
    pub fn execute(&self, name: &str, frag_codes: &[i32], pat_codes: &[i32]) -> Result<PassOutput> {
        let lv = self
            .variants
            .get(name)
            .ok_or_else(|| anyhow!("unknown variant {name} (have {:?})", self.variant_names()))?;
        let v = &lv.variant;
        let want = v.rows * v.frag_chars;
        if frag_codes.len() > want {
            anyhow::bail!("fragment buffer {} exceeds variant capacity {want}", frag_codes.len());
        }
        if pat_codes.len() != v.pat_chars {
            anyhow::bail!("pattern length {} != variant pat_chars {}", pat_codes.len(), v.pat_chars);
        }

        let mut frag = frag_codes.to_vec();
        frag.resize(want, 0);
        let frag_lit = xla::Literal::vec1(&frag)
            .reshape(&[v.rows as i64, v.frag_chars as i64])
            .map_err(|e| anyhow!("reshape fragment: {e}"))?;
        let pat_lit = xla::Literal::vec1(pat_codes);

        let result = lv
            .exe
            .execute::<xla::Literal>(&[frag_lit, pat_lit])
            .map_err(|e| anyhow!("execute {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        // aot.py lowers with return_tuple=True: (scores, best_loc, best_score).
        let (scores, best_loc, best_score) =
            result.to_tuple3().map_err(|e| anyhow!("untuple: {e}"))?;
        Ok(PassOutput {
            scores: scores.to_vec::<i32>().map_err(|e| anyhow!("scores: {e}"))?,
            best_loc: best_loc.to_vec::<i32>().map_err(|e| anyhow!("best_loc: {e}"))?,
            best_score: best_score.to_vec::<i32>().map_err(|e| anyhow!("best_score: {e}"))?,
            n_alignments: v.n_alignments(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dna::{encode, score_profile};
    use crate::util::Rng;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<Runtime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Runtime::load(&dir).expect("runtime load"))
    }

    #[test]
    fn loads_all_manifest_variants() {
        let Some(rt) = runtime() else { return };
        assert!(rt.variant_names().contains(&"dna_small"));
        assert_eq!(rt.variant("dna_small").unwrap().rows, 256);
    }

    /// The cross-layer correctness keystone: the AOT'd XLA artifact
    /// (L1 Pallas kernel through L2 JAX model) agrees with the rust
    /// CPU oracle on random data.
    #[test]
    fn xla_scores_match_cpu_oracle() {
        let Some(rt) = runtime() else { return };
        let v = rt.variant("dna_small").unwrap().clone();
        let mut rng = Rng::new(99);
        let frags: Vec<Vec<u8>> = (0..v.rows).map(|_| encode(&rng.dna(v.frag_chars))).collect();
        let pattern = encode(&rng.dna(v.pat_chars));

        let frag_i32: Vec<i32> =
            frags.iter().flat_map(|f| f.iter().map(|&c| c as i32)).collect();
        let pat_i32: Vec<i32> = pattern.iter().map(|&c| c as i32).collect();
        let out = rt.execute("dna_small", &frag_i32, &pat_i32).unwrap();

        for (r, frag) in frags.iter().enumerate().step_by(17) {
            let want = score_profile(frag, &pattern);
            for (loc, &w) in want.iter().enumerate() {
                assert_eq!(out.score(r, loc), w as i32, "row {r} loc {loc}");
            }
            let best = want.iter().copied().max().unwrap() as i32;
            assert_eq!(out.best_score[r], best, "row {r} best");
            assert_eq!(want[out.best_loc[r] as usize] as i32, best, "row {r} best loc");
        }
    }

    #[test]
    fn short_input_is_padded() {
        let Some(rt) = runtime() else { return };
        let v = rt.variant("dna_small").unwrap().clone();
        // Only 2 rows provided; the rest pad to 'A'*frag.
        let frag_i32 = vec![3i32; 2 * v.frag_chars];
        let pat_i32 = vec![3i32; v.pat_chars];
        let out = rt.execute("dna_small", &frag_i32, &pat_i32).unwrap();
        assert_eq!(out.best_score[0], v.pat_chars as i32);
        assert_eq!(out.best_score[2], 0, "padded row must score zero vs all-T pattern");
    }

    #[test]
    fn wrong_pattern_length_rejected() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("dna_small", &[0; 64], &[0; 3]).is_err());
    }

    #[test]
    fn unknown_variant_rejected() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("nope", &[], &[]).is_err());
    }
}

//! PJRT runtime: load and execute the AOT artifacts (`artifacts/
//! *.hlo.txt`) produced by `python/compile/aot.py`.
//!
//! This is the only place the stack touches XLA at runtime. Python is
//! never on the request path: `make artifacts` lowers the L2 model
//! once, and this module compiles the HLO text onto the PJRT CPU
//! client at startup (one executable per shape variant) and serves
//! score computations from then on.
//!
//! The offline build image vendors no PJRT crate, so [`engine`] links
//! against [`xla_stub`] — an API-compatible stand-in that fails client
//! construction with a clear message. Artifact-gated tests and drivers
//! skip (or fall back to the bit-level engine) when
//! `artifacts/manifest.txt` is absent, which it is in this tree.

pub mod engine;
pub mod manifest;
pub mod xla_stub;

pub use engine::{PassOutput, Runtime};
pub use manifest::{Manifest, Variant};

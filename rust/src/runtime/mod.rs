//! PJRT runtime: load and execute the AOT artifacts (`artifacts/
//! *.hlo.txt`) produced by `python/compile/aot.py`.
//!
//! This is the only place the stack touches XLA at runtime. Python is
//! never on the request path: `make artifacts` lowers the L2 model
//! once, and this module compiles the HLO text onto the PJRT CPU
//! client at startup (one executable per shape variant) and serves
//! score computations from then on.

pub mod engine;
pub mod manifest;

pub use engine::{PassOutput, Runtime};
pub use manifest::{Manifest, Variant};

//! Artifact manifest: the whitespace-separated variant table written
//! by `python/compile/aot.py` (`manifest.txt`).

use crate::Result;
use anyhow::{bail, Context};
use std::path::{Path, PathBuf};

/// One exported shape variant of the L2 model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Variant {
    /// Variant name (e.g. `dna_small`).
    pub name: String,
    /// Rows per executable invocation.
    pub rows: usize,
    /// Fragment length, characters.
    pub frag_chars: usize,
    /// Pattern length, characters.
    pub pat_chars: usize,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
}

impl Variant {
    /// Alignments per row this variant computes.
    pub fn n_alignments(&self) -> usize {
        self.frag_chars - self.pat_chars + 1
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Artifact directory the manifest came from.
    pub dir: PathBuf,
    /// Exported variants.
    pub variants: Vec<Variant>,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`. Format per line:
    /// `name rows frag_chars pat_chars file`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let mut variants = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 5 {
                bail!("manifest line {}: expected 5 fields, got {}", lineno + 1, f.len());
            }
            let v = Variant {
                name: f[0].to_string(),
                rows: f[1].parse().context("rows")?,
                frag_chars: f[2].parse().context("frag_chars")?,
                pat_chars: f[3].parse().context("pat_chars")?,
                file: f[4].to_string(),
            };
            if v.pat_chars > v.frag_chars || v.rows == 0 {
                bail!("manifest line {}: inconsistent variant {v:?}", lineno + 1);
            }
            variants.push(v);
        }
        if variants.is_empty() {
            bail!("manifest {} lists no variants", path.display());
        }
        Ok(Manifest { dir: dir.to_path_buf(), variants })
    }

    /// Find a variant by name.
    pub fn variant(&self, name: &str) -> Option<&Variant> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// Absolute path of a variant's HLO file.
    pub fn hlo_path(&self, v: &Variant) -> PathBuf {
        self.dir.join(&v.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(content: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("crampm-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), content).unwrap();
        dir
    }

    #[test]
    fn parses_well_formed_manifest() {
        let dir = write_manifest("a 256 64 16 a.hlo.txt\nb 512 16 16 b.hlo.txt\n");
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.variants.len(), 2);
        let a = m.variant("a").unwrap();
        assert_eq!((a.rows, a.frag_chars, a.pat_chars), (256, 64, 16));
        assert_eq!(a.n_alignments(), 49);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_malformed_lines() {
        let dir = write_manifest("bad line here\n");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_inconsistent_variant() {
        let dir = write_manifest("x 256 16 64 x.hlo.txt\n"); // pat > frag
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}

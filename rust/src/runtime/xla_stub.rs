//! Offline stand-in for the `xla` (PJRT) bindings.
//!
//! The build image vendors no PJRT/XLA crate, so this module mirrors
//! the small slice of the `xla` API that [`crate::runtime::engine`]
//! consumes and fails — with an actionable message — at the first
//! operation that would need the real runtime ([`PjRtClient::cpu`]).
//!
//! Every artifact-gated test, bench, and example checks for
//! `artifacts/manifest.txt` before exercising the XLA path and skips
//! (or falls back to [`crate::engine::EngineSpec::Bitsim`], resolved
//! through [`crate::engine::registry`] like every other engine) when
//! it is absent, so the default build stays green end to end. Swapping
//! the real bindings back in is one line: re-point the `xla` alias at
//! the top of `runtime/engine.rs` from this module to the crate.

use std::fmt;

/// Displayable error mirroring `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn stub() -> Self {
        Error(
            "PJRT/XLA bindings are not vendored in this build; score with \
             EngineSpec::Cpu or EngineSpec::Bitsim instead (see README.md)"
                .to_string(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias used by the stub API.
pub type XlaResult<T> = std::result::Result<T, Error>;

/// Stub PJRT client — construction always fails, so no other stub
/// method is reachable on the hot path.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real binding constructs a CPU PJRT client; the stub reports
    /// that the runtime is unavailable.
    pub fn cpu() -> XlaResult<Self> {
        Err(Error::stub())
    }

    /// Platform name (diagnostics).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile an HLO computation onto the client.
    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments, returning per-device output
    /// buffers.
    pub fn execute<T>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer contents as a literal.
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(Error::stub())
    }
}

/// Stub HLO module proto (text-parsed in the real binding).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: &str) -> XlaResult<Self> {
        Err(Error::stub())
    }
}

/// Stub XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Stub host literal.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(_data: &[i32]) -> Self {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> XlaResult<Literal> {
        Err(Error::stub())
    }

    /// Destructure a 3-tuple literal.
    pub fn to_tuple3(&self) -> XlaResult<(Literal, Literal, Literal)> {
        Err(Error::stub())
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_fails_with_actionable_message() {
        let err = PjRtClient::cpu().err().expect("stub client must not construct");
        let msg = err.to_string();
        assert!(msg.contains("not vendored"), "unhelpful stub error: {msg}");
        assert!(msg.contains("Bitsim"), "stub error must point at a working engine: {msg}");
    }

    #[test]
    fn stub_literals_construct_but_do_not_execute() {
        let lit = Literal::vec1(&[1, 2, 3]);
        assert!(lit.reshape(&[3, 1]).is_err());
        assert!(lit.to_vec::<i32>().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}

//! Deterministic fault injection for the stochastic substrate.
//!
//! CRAM-PM's gates are thermally-activated MTJ switches: real in-memory
//! logic flips output bits at a nonzero per-operation rate, writes
//! disturb neighbouring cells, and readout sensing misfires — the
//! reliability picture computational phase-change memory (Sebastian et
//! al.) and STT-MRAM compute substrates (Jain et al.) share. The bitsim
//! models a perfect device unless told otherwise; this module is the
//! "otherwise".
//!
//! A [`FaultPlan`] carries one per-op flip rate per fault channel
//! ([`FaultChannel::Gate`] / [`FaultChannel::Write`] /
//! [`FaultChannel::Read`]) plus a seed. Plans are **seed-splittable**:
//! [`FaultPlan::session`] derives an independent deterministic stream
//! per `(pattern, attempt)`, so re-executing a work item under
//! protection draws *fresh* faults (re-execution voting would be
//! useless against replayed ones) while the whole run stays
//! reproducible bit for bit under a fixed plan seed.
//!
//! Within a session, faults are sampled by **geometric gap skipping**:
//! instead of one Bernoulli draw per device op (the hot loop does
//! millions), the session draws the gap to the next faulty op from the
//! geometric distribution `floor(ln U / ln(1-p))` and counts ops down
//! to it — statistically identical, nearly free when rates are low,
//! and exactly free (`u64::MAX` sentinel, one integer compare) when a
//! channel's rate is zero. At most one flip fires per faulty op, which
//! is exact to first order for the `p ≪ 1` rates physical devices have.
//!
//! The plan also carries the two **test-only supervision hooks** the
//! coordinator's lane-respawn machinery is proven against:
//! [`FaultPlan::panic_on_item`] (the executor panics mid-batch, a
//! bounded number of times) and [`FaultPlan::stall_on_item`] (the
//! executor wedges for a fixed duration, tripping the stall detector).
//! Both decrement a shared atomic budget so a respawned lane's retry of
//! the same item succeeds — that is what makes "bit-identical after
//! respawn" testable.

use crate::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// The three device-error channels of the array model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultChannel {
    /// Gate-output flip: one bulk gate application writes a wrong bit
    /// into its output column (thermally-activated MTJ switching).
    Gate = 0,
    /// Write-disturb flip: staging a code bit into the array corrupts a
    /// cell.
    Write = 1,
    /// Readout flip: the sense path reports a wrong bit of an assembled
    /// row score.
    Read = 2,
}

/// A bounded test-only trigger: fire (panic or stall) on a specific
/// pattern id, `remaining` times total across all lanes and attempts.
#[derive(Debug, Clone)]
struct ItemTrigger {
    pattern_id: usize,
    remaining: Arc<AtomicUsize>,
}

impl ItemTrigger {
    fn new(pattern_id: usize, times: usize) -> Self {
        ItemTrigger { pattern_id, remaining: Arc::new(AtomicUsize::new(times)) }
    }

    /// Decrement-if-positive; true when this call claimed a firing.
    fn claim(&self, pattern_id: usize) -> bool {
        if pattern_id != self.pattern_id {
            return false;
        }
        self.remaining
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1))
            .is_ok()
    }
}

/// A deterministic, seed-splittable device-fault plan.
///
/// Cloning shares the panic/stall budgets (they are process-wide
/// triggers) but the rate channels are pure parameters — every lane
/// and attempt derives its own independent stream via
/// [`FaultPlan::session`].
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Per-op probability of a gate-output flip.
    pub gate_flip_rate: f64,
    /// Per-op probability of a write-disturb flip.
    pub write_flip_rate: f64,
    /// Per-op probability of a readout flip.
    pub read_flip_rate: f64,
    /// Root seed every session stream splits from.
    pub seed: u64,
    panic_trigger: Option<ItemTrigger>,
    stall_trigger: Option<(ItemTrigger, u64)>,
}

impl FaultPlan {
    /// A plan with per-channel flip rates under a root seed.
    pub fn rates(gate: f64, write: f64, read: f64, seed: u64) -> Self {
        FaultPlan {
            gate_flip_rate: gate,
            write_flip_rate: write,
            read_flip_rate: read,
            seed,
            panic_trigger: None,
            stall_trigger: None,
        }
    }

    /// Test-only supervision hook: the executor panics when it picks up
    /// `pattern_id` — once. The budget is shared across clones, so the
    /// respawned lane's retry of the same item runs clean.
    pub fn panic_on_item(pattern_id: usize) -> Self {
        Self::panic_on_item_times(pattern_id, 1)
    }

    /// [`FaultPlan::panic_on_item`] with an explicit firing budget
    /// (`times` panics total, then the item executes normally) — used
    /// to drive a lane past its restart quarantine.
    pub fn panic_on_item_times(pattern_id: usize, times: usize) -> Self {
        FaultPlan { panic_trigger: Some(ItemTrigger::new(pattern_id, times)), ..Self::default() }
    }

    /// Test-only supervision hook: the executor wedges (sleeps
    /// `millis`) when it picks up `pattern_id` — once. Long enough a
    /// stall trips the coordinator's typed stall detector instead of
    /// hanging the run forever.
    pub fn stall_on_item(pattern_id: usize, millis: u64) -> Self {
        FaultPlan {
            stall_trigger: Some((ItemTrigger::new(pattern_id, 1), millis)),
            ..Self::default()
        }
    }

    /// Whether any rate channel can fire (the zero-cost-when-disabled
    /// gate: engines skip all fault plumbing when this is false).
    pub fn rates_enabled(&self) -> bool {
        self.gate_flip_rate > 0.0 || self.write_flip_rate > 0.0 || self.read_flip_rate > 0.0
    }

    /// Fire the test-only supervision hooks for `pattern_id`: panics or
    /// sleeps if an armed trigger claims this execution. Called by the
    /// lane executor at item pickup, inside its `catch_unwind`.
    pub fn trip(&self, pattern_id: usize) {
        if let Some((trigger, millis)) = &self.stall_trigger {
            if trigger.claim(pattern_id) {
                std::thread::sleep(std::time::Duration::from_millis(*millis));
            }
        }
        if let Some(trigger) = &self.panic_trigger {
            if trigger.claim(pattern_id) {
                panic!("fault plan: injected executor panic on pattern {pattern_id}");
            }
        }
    }

    /// Split an independent deterministic fault stream for one
    /// `(pattern, attempt)` execution.
    pub fn session(&self, pattern_id: usize, attempt: u64) -> FaultSession {
        let seed = mix(self.seed)
            .wrapping_add(mix((pattern_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)))
            .wrapping_add(mix(attempt.wrapping_mul(0xD1B5_4A32_D192_ED03) ^ 0xA24B_AED4_963E_E407));
        FaultSession {
            rng: Rng::new(seed),
            channels: [
                Channel::new(self.gate_flip_rate),
                Channel::new(self.write_flip_rate),
                Channel::new(self.read_flip_rate),
            ],
            injected: 0,
        }
    }
}

/// splitmix64 finalizer — the standard seed-splitting mix.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One rate channel's skip-sampling state.
#[derive(Debug, Clone)]
struct Channel {
    /// Ops left before the next faulty one; `u64::MAX` when disabled.
    until_next: u64,
    /// `ln(1 − p)`; `0.0` doubles as the disabled marker (p ≤ 0).
    ln_keep: f64,
}

impl Channel {
    fn new(p: f64) -> Self {
        if p <= 0.0 {
            return Channel { until_next: u64::MAX, ln_keep: 0.0 };
        }
        // The first gap is drawn lazily on first use so construction
        // costs no RNG draws for channels that never see an op.
        Channel { until_next: 0, ln_keep: (1.0 - p.min(1.0)).ln() }
    }
}

/// The deterministic per-execution fault stream
/// ([`FaultPlan::session`]): counts device ops per channel and says
/// which ones flip.
#[derive(Debug, Clone)]
pub struct FaultSession {
    rng: Rng,
    channels: [Channel; 3],
    injected: usize,
}

impl FaultSession {
    /// Account `ops` device operations on `channel`; `flip(offset)` is
    /// called for each faulty op (0-based offset within this batch).
    /// The caller maps the offset back to the device coordinate (cell,
    /// column, row) it was about to touch.
    pub fn flips(&mut self, channel: FaultChannel, ops: u64, mut flip: impl FnMut(u64)) {
        let i = channel as usize;
        if self.channels[i].ln_keep == 0.0 {
            return; // disabled channel: one compare, no draws
        }
        if self.channels[i].until_next == 0 {
            // Lazily draw the channel's first gap.
            self.channels[i].until_next = self.gap(i);
        }
        let mut offset = 0u64;
        loop {
            let until = self.channels[i].until_next;
            let left = ops - offset;
            if until > left {
                self.channels[i].until_next = until - left;
                return;
            }
            // The `until`-th op from here (1-based) is the faulty one.
            offset += until;
            flip(offset - 1);
            self.injected += 1;
            self.channels[i].until_next = self.gap(i);
            if offset >= ops {
                return;
            }
        }
    }

    /// Whether a single op on `channel` faults (the CPU engine's
    /// per-candidate shape, where one score is the whole device op).
    pub fn one(&mut self, channel: FaultChannel) -> bool {
        let mut hit = false;
        self.flips(channel, 1, |_| hit = true);
        hit
    }

    /// Uniform draw in `0..n` — which bit/cell a firing flip lands on.
    pub fn pick(&mut self, n: usize) -> usize {
        self.rng.below(n.max(1))
    }

    /// Corrupt one assembled candidate score as the CPU reference
    /// device would see it: each enabled channel contributes one op for
    /// this candidate, and a firing op flips one bit of the
    /// `width`-bit score. (The CPU engine has no physical gate/write
    /// ops to hook, so all three channels collapse onto the score.)
    pub fn corrupt_score(&mut self, score: usize, width: usize) -> usize {
        let mut s = score;
        for channel in [FaultChannel::Gate, FaultChannel::Write, FaultChannel::Read] {
            if self.one(channel) {
                s ^= 1usize << self.pick(width.max(1));
            }
        }
        s
    }

    /// Faults injected by this session so far.
    pub fn injected(&self) -> usize {
        self.injected
    }

    /// Draw the next geometric gap for channel `i`: the number of clean
    /// ops before the faulty one, plus one (i.e. the 1-based index of
    /// the next faulty op from now).
    fn gap(&mut self, i: usize) -> u64 {
        let ln_keep = self.channels[i].ln_keep;
        if ln_keep == f64::NEG_INFINITY {
            return 1; // p = 1: every op faults
        }
        // U ∈ (0,1]: next_f64 can return 0, which would send ln to -∞;
        // clamp to the smallest positive normal instead (a gap cap,
        // not a bias, at these magnitudes).
        let u = self.rng.next_f64().max(f64::MIN_POSITIVE);
        let g = (u.ln() / ln_keep).floor() + 1.0;
        if g >= u64::MAX as f64 {
            u64::MAX
        } else {
            g as u64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_plan_never_fires() {
        let plan = FaultPlan::default();
        assert!(!plan.rates_enabled());
        let mut s = plan.session(0, 0);
        for ch in [FaultChannel::Gate, FaultChannel::Write, FaultChannel::Read] {
            s.flips(ch, 1_000_000, |_| panic!("disabled channel fired"));
        }
        assert_eq!(s.injected(), 0);
    }

    #[test]
    fn sessions_are_deterministic_and_split_by_pattern_and_attempt() {
        let plan = FaultPlan::rates(1e-3, 1e-3, 1e-3, 42);
        let collect = |pid: usize, attempt: u64| {
            let mut s = plan.session(pid, attempt);
            let mut offs = Vec::new();
            s.flips(FaultChannel::Gate, 100_000, |o| offs.push(o));
            (offs, s.injected())
        };
        let (a1, n1) = collect(3, 0);
        let (a2, n2) = collect(3, 0);
        assert_eq!(a1, a2, "same (pattern, attempt) must replay identically");
        assert_eq!(n1, n2);
        let (b, _) = collect(3, 1);
        let (c, _) = collect(4, 0);
        assert!(n1 > 0, "1e-3 over 100k ops fires w.h.p.");
        assert_ne!(a1, b, "attempts must draw fresh faults");
        assert_ne!(a1, c, "patterns must draw independent streams");
    }

    #[test]
    fn geometric_skipping_matches_the_rate() {
        let p = 2e-3;
        let plan = FaultPlan::rates(0.0, p, 0.0, 7);
        let ops = 500_000u64;
        let mut s = plan.session(0, 0);
        let mut count = 0usize;
        s.flips(FaultChannel::Write, ops, |o| {
            assert!(o < ops);
            count += 1;
        });
        let expect = p * ops as f64;
        // 500k ops at 2e-3 → mean 1000, σ ≈ 31.6; ±20 % is > 6σ.
        assert!(
            (count as f64) > expect * 0.8 && (count as f64) < expect * 1.2,
            "observed {count} flips, expected ≈{expect:.0}"
        );
        assert_eq!(s.injected(), count);
    }

    #[test]
    fn split_batches_fire_like_one_batch() {
        // Counting 10 × 10k ops must replay the same faults as 1 × 100k:
        // the gap state carries across `flips` calls.
        let plan = FaultPlan::rates(1e-3, 0.0, 0.0, 99);
        let mut s1 = plan.session(5, 2);
        let mut whole = Vec::new();
        s1.flips(FaultChannel::Gate, 100_000, |o| whole.push(o));
        let mut s2 = plan.session(5, 2);
        let mut parts = Vec::new();
        for chunk in 0..10u64 {
            s2.flips(FaultChannel::Gate, 10_000, |o| parts.push(chunk * 10_000 + o));
        }
        assert_eq!(whole, parts);
    }

    #[test]
    fn certain_rate_fires_every_op() {
        let plan = FaultPlan::rates(1.0, 0.0, 0.0, 1);
        let mut s = plan.session(0, 0);
        let mut offs = Vec::new();
        s.flips(FaultChannel::Gate, 5, |o| offs.push(o));
        assert_eq!(offs, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn corrupt_score_stays_within_width() {
        let plan = FaultPlan::rates(0.2, 0.2, 0.2, 11);
        let mut s = plan.session(1, 0);
        let width = 5usize;
        let mut changed = 0usize;
        for _ in 0..2_000 {
            let c = s.corrupt_score(16, width);
            if c != 16 {
                changed += 1;
            }
            assert!(c < 1 << width, "flip escaped the score width: {c}");
        }
        assert!(changed > 0, "0.2-per-channel rates must corrupt some scores");
    }

    #[test]
    fn panic_budget_is_shared_and_bounded() {
        let plan = FaultPlan::panic_on_item(7);
        let clone = plan.clone();
        plan.trip(3); // wrong item: no-op
        let fired = std::panic::catch_unwind(|| clone.trip(7));
        assert!(fired.is_err(), "armed trigger must panic on its item");
        // Budget exhausted (shared across clones): the retry runs clean.
        plan.trip(7);
        clone.trip(7);
    }

    #[test]
    fn stall_trigger_sleeps_once() {
        let plan = FaultPlan::stall_on_item(2, 10);
        let t0 = std::time::Instant::now();
        plan.trip(2);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(10));
        let t1 = std::time::Instant::now();
        plan.trip(2); // budget spent: immediate
        assert!(t1.elapsed() < std::time::Duration::from_millis(10));
    }
}

//! Cross-layer integration tests: the bit-level array, the AOT XLA
//! artifact, the CPU oracle and the coordinator must all tell the same
//! story on the same workloads.

use cram_pm::bench_apps::dna::DnaWorkload;
use cram_pm::coordinator::{Coordinator, CoordinatorConfig, EngineSpec};
use cram_pm::dna::encode;
use cram_pm::isa::PresetMode;
use cram_pm::scheduler::{NaiveScheduler, PatternScheduler};
use cram_pm::sim::{DnaPassModel, SystemConfig};
use cram_pm::tech::Technology;

fn artifacts_available() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.txt").exists()
}

/// The keystone: all three functional engines agree per pattern on a
/// non-trivial workload with read errors (so scores are not all
/// perfect and ties/ordering paths get exercised).
#[test]
fn three_engines_agree_end_to_end() {
    let w = DnaWorkload::generate(16_384, 64, 16, 0.05, 321);
    let fragments = w.fragments(64, 16);

    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut results = Vec::new();
    for engine in
        [EngineSpec::Cpu, EngineSpec::Bitsim, EngineSpec::xla("dna_small", &artifacts)]
    {
        if matches!(engine, EngineSpec::Xla { .. }) && !artifacts_available() {
            eprintln!("skipping XLA engine: run `make artifacts`");
            continue;
        }
        let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
        cfg.engine = engine.clone();
        let coord = Coordinator::new(cfg, fragments.clone()).unwrap();
        let (res, metrics) = coord.run(&w.patterns).unwrap();
        assert_eq!(metrics.patterns, w.patterns.len());
        results.push((engine, res));
    }
    let (_, ref base) = results[0];
    for (engine, res) in &results[1..] {
        for (a, b) in base.iter().zip(res) {
            assert_eq!(
                a.best.map(|x| x.score),
                b.best.map(|x| x.score),
                "{engine:?} disagrees with CPU on pattern {}",
                a.pattern_id
            );
        }
    }
}

/// Multi-lane execute is bit-identical to single-lane, across engines
/// and routing modes — the sharding refactor's keystone: lanes change
/// wall-clock, never answers.
#[test]
fn multi_lane_pipeline_is_bit_identical_to_single_lane() {
    let w = DnaWorkload::generate(4_096, 16, 16, 0.05, 55);
    let fragments = w.fragments(64, 16);
    for engine in [EngineSpec::Cpu, EngineSpec::Bitsim] {
        for oracular in [Some((8, 24)), None] {
            let run_with = |lanes: usize| {
                let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
                cfg.engine = engine.clone();
                cfg.oracular = oracular;
                cfg.lanes = lanes;
                Coordinator::new(cfg, fragments.clone()).unwrap().run(&w.patterns).unwrap().0
            };
            let single = run_with(1);
            let multi = run_with(4);
            assert_eq!(single.len(), multi.len());
            for (a, b) in single.iter().zip(&multi) {
                assert_eq!(a.pattern_id, b.pattern_id);
                assert_eq!(
                    a.best.map(|x| (x.score, x.row, x.loc)),
                    b.best.map(|x| (x.score, x.row, x.loc)),
                    "{engine:?} oracular={oracular:?} pattern {}",
                    a.pattern_id
                );
            }
        }
    }
}

/// Naive broadcast finds the global best (matches the unrestricted
/// oracle), and Oracular never reports a better score than Naive.
#[test]
fn oracular_is_sound_but_possibly_incomplete() {
    let w = DnaWorkload::generate(8_192, 48, 16, 0.10, 99);
    let fragments = w.fragments(64, 16);

    let mut naive_cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    naive_cfg.engine = EngineSpec::Cpu;
    naive_cfg.oracular = None;
    let naive = Coordinator::new(naive_cfg, fragments.clone()).unwrap();
    let (naive_res, _) = naive.run(&w.patterns).unwrap();

    let mut orac_cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    orac_cfg.engine = EngineSpec::Cpu;
    let orac = Coordinator::new(orac_cfg, fragments.clone()).unwrap();
    let (orac_res, _) = orac.run(&w.patterns).unwrap();

    let oracle = cram_pm::baselines::CpuMatcher::new(fragments);
    for ((n, o), pattern) in naive_res.iter().zip(&orac_res).zip(&w.patterns) {
        let global = oracle.best(pattern).unwrap();
        assert_eq!(n.best.unwrap().score, global.score, "naive must equal the oracle");
        assert!(
            o.best.map_or(0, |b| b.score) <= global.score,
            "oracular can't beat the oracle"
        );
    }
}

/// The step model is internally consistent across designs: for any
/// configuration, OptSpeedup ≥ 1, oracular packing multiplies rate
/// exactly, and energy is invariant to preset scheduling.
#[test]
fn step_model_design_space_consistency() {
    for tech in Technology::ALL {
        for (rows, frag, pat) in [(128, 64, 16), (512, 128, 32), (2048, 256, 100)] {
            let mut cfg_std = SystemConfig::small(tech, PresetMode::Standard);
            cfg_std.rows = rows;
            cfg_std.frag_chars = frag;
            cfg_std.pat_chars = pat;
            let mut cfg_opt = cfg_std;
            cfg_opt.preset_mode = PresetMode::Gang;

            let std_cost = DnaPassModel::new(cfg_std).pass_cost();
            let opt_cost = DnaPassModel::new(cfg_opt).pass_cost();
            assert!(
                std_cost.masked_latency > opt_cost.masked_latency,
                "{tech} {rows}x{frag}: opt must be faster"
            );
            let e_ratio = std_cost.energy / opt_cost.energy;
            assert!(
                (0.8..1.25).contains(&e_ratio),
                "{tech} {rows}x{frag}: preset scheduling changed energy by {e_ratio}"
            );
        }
    }
}

/// Naive scheduler packing matches the throughput model's assumption:
/// exactly one pattern per pass, all rows occupied.
#[test]
fn naive_schedule_shape_matches_throughput_model() {
    let s = NaiveScheduler::new(4, 128);
    let passes = s.schedule(10);
    assert_eq!(passes.len(), 10);
    assert!(passes.iter().all(|p| p.assignments.len() == 512 && p.distinct_patterns() == 1));
}

/// Planted-needle recall through the full pipeline: reads with planted
/// unique motifs must be found at the right fragment by every engine.
#[test]
fn planted_motif_recovered_at_correct_row() {
    // Build a reference with a unique motif at a known position.
    let mut w = DnaWorkload::generate(4096, 1, 16, 0.0, 5);
    let motif = b"ACGTTGCAACGGTTAA";
    let pos = 1000;
    w.reference[pos..pos + 16].copy_from_slice(motif);
    let fragments = w.fragments(64, 16);

    let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    cfg.engine = EngineSpec::Bitsim;
    let coord = Coordinator::new(cfg, fragments.clone()).unwrap();
    let (res, _) = coord.run(&[encode(motif)]).unwrap();
    let best = res[0].best.expect("motif must be found");
    assert_eq!(best.score, 16);
    // The reported row must actually contain the motif at that loc.
    let frag = &fragments[best.row];
    assert_eq!(
        cram_pm::dna::similarity(frag, &encode(motif), best.loc),
        16,
        "annotated (row, loc) does not contain the motif"
    );
}

/// Paper-scale configuration invariants (§3.4 sizing).
#[test]
fn paper_configuration_invariants() {
    let cfg = SystemConfig::paper_dna(Technology::NearTerm, PresetMode::Gang);
    let geo = cfg.geometry();
    // Row width within the §3.4 interconnect bound for the binding
    // 2-input gate at the top of its window — checked against the
    // actual interconnect analysis.
    let wire = cram_pm::tech::interconnect::InterconnectModel::at_22nm();
    let mtj = cram_pm::tech::MtjParams::near_term();
    let bound =
        cram_pm::tech::interconnect::max_row_width(&mtj, &wire, cram_pm::gates::GateKind::Copy);
    assert!(
        geo.cols < bound.max_cells * 4,
        "layout ({} cols) grossly exceeds interconnect reach ({})",
        geo.cols,
        bound.max_cells
    );
    // Substrate capacity covers the human genome.
    assert!(cfg.reference_capacity() >= 3_000_000_000);
}

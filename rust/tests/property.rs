//! Property-based tests (in-tree PRNG loops standing in for proptest —
//! the offline image vendors no proptest; seeds are fixed so failures
//! reproduce).
//!
//! Invariants covered:
//! * codegen: every gate's output is pre-set first, no gate reads its
//!   own output, programs fit their layout, preset counts are
//!   mode-invariant — for random geometries;
//! * array semantics: Algorithm 1 equals the character-level oracle for
//!   random fragments/patterns/geometries; compute is non-destructive;
//! * scheduler: passes never double-book a row, every seedable pattern
//!   appears in ≥1 pass, candidates are sound (candidate rows really
//!   share a k-mer), and pass assignments are a subset of the k-mer
//!   candidate set;
//! * coordinator: result ordering and count invariants under random
//!   pool sizes, and lane-count invariance of the merged results;
//! * simd: every vector kernel available on this host (avx2/neon) is
//!   bit-identical to the scalar oracle — at the CPU-engine block
//!   path, the bitsim word-op, and the forced-dispatch coordinator
//!   levels. CI re-runs this whole suite under each forced
//!   `CRAM_PM_SIMD` value on both architectures.

use cram_pm::array::{CramArray, RowLayout};
use cram_pm::bench_apps::dna::DnaWorkload;
use cram_pm::coordinator::{Coordinator, CoordinatorConfig, EngineSpec};
use cram_pm::dna::{encode, score_profile, Encoded};
use cram_pm::isa::{CodeGen, MicroInstr, PresetMode};
use cram_pm::scheduler::{OracularScheduler, PatternScheduler, RowAddr, ShardMap};
use cram_pm::util::Rng;
use std::collections::HashSet;

/// Random (frag, pat) geometry, small enough to execute quickly.
fn random_geometry(rng: &mut Rng) -> (usize, usize) {
    let pat = rng.range(1, 24);
    let frag = pat + rng.range(0, 48);
    (frag, pat)
}

fn sized_layout(frag: usize, pat: usize, mode: PresetMode) -> RowLayout {
    let probe = RowLayout::new(frag, pat, usize::MAX / 2);
    let mut cg = CodeGen::new(probe, mode);
    let _ = cg.alignment_program(0, true);
    RowLayout::new(frag, pat, cg.stats().scratch_high_water)
}

#[test]
fn prop_codegen_safety_invariants() {
    let mut rng = Rng::new(0xA11CE);
    for iter in 0..40 {
        let (frag, pat) = random_geometry(&mut rng);
        for mode in [PresetMode::Standard, PresetMode::Gang] {
            let layout = sized_layout(frag, pat, mode);
            let mut cg = CodeGen::new(layout, mode);
            let loc = rng.below(layout.n_alignments()) as u32;
            let prog = cg.alignment_program(loc, rng.bool());

            let mut preset: HashSet<u32> = HashSet::new();
            for (_, instr) in &prog.instrs {
                match instr {
                    MicroInstr::Preset { col, .. } | MicroInstr::GangPreset { col, .. } => {
                        preset.insert(*col);
                    }
                    MicroInstr::Gate { out, .. } => {
                        assert!(
                            preset.contains(out),
                            "iter {iter} {mode:?} frag={frag} pat={pat}: unpreset gate output"
                        );
                        assert!(
                            !instr.gate_inputs().contains(out),
                            "gate output aliases an input"
                        );
                    }
                    _ => {}
                }
            }
            let max = prog.max_column().unwrap() as usize;
            assert!(max < layout.total_cols(), "program exceeds layout");
        }
    }
}

#[test]
fn prop_algorithm1_equals_oracle_random_geometries() {
    let mut rng = Rng::new(0xBEE5);
    for iter in 0..25 {
        let (frag_chars, pat_chars) = random_geometry(&mut rng);
        let rows = rng.range(1, 70);
        let mode = if rng.bool() { PresetMode::Gang } else { PresetMode::Standard };
        let layout = sized_layout(frag_chars, pat_chars, mode);

        let fragments: Vec<Vec<u8>> = (0..rows).map(|_| encode(&rng.dna(frag_chars))).collect();
        let pattern = encode(&rng.dna(pat_chars));

        let mut arr = CramArray::new(rows, layout.total_cols());
        for (r, f) in fragments.iter().enumerate() {
            arr.write_encoded(r, layout.frag_col() as usize, &Encoded { codes: f.clone() });
        }
        arr.broadcast_encoded(layout.pat_col() as usize, &Encoded { codes: pattern.clone() });

        let mut cg = CodeGen::new(layout, mode);
        // Spot-check a random subset of alignments (full sweep is the
        // lib test; here we vary geometry broadly instead).
        for _ in 0..3.min(layout.n_alignments()) {
            let loc = rng.below(layout.n_alignments()) as u32;
            let out = arr.execute(&cg.alignment_program(loc, true)).unwrap();
            for (r, f) in fragments.iter().enumerate() {
                let want = score_profile(f, &pattern)[loc as usize] as u64;
                assert_eq!(
                    out.scores[0][r], want,
                    "iter {iter} rows={rows} frag={frag_chars} pat={pat_chars} loc={loc} row {r}"
                );
            }
        }

        // Non-destructive: fragment and pattern compartments intact.
        for (r, f) in fragments.iter().enumerate() {
            let bits = arr.read_row_bits(r, layout.frag_col() as usize, 2 * frag_chars);
            assert_eq!(Encoded::from_bits(&bits).codes, *f, "fragment clobbered");
        }
    }
}

#[test]
fn prop_oracular_candidates_sound_and_schedules_complete() {
    let mut rng = Rng::new(0xD1CE);
    for _ in 0..10 {
        let n_rows = rng.range(8, 64);
        let frag_chars = rng.range(40, 120);
        let pat_chars = rng.range(12, 24);
        let k = rng.range(4, pat_chars.min(10));

        let fragments: Vec<Vec<u8>> = (0..n_rows).map(|_| encode(&rng.dna(frag_chars))).collect();
        let n_pats = rng.range(4, 40);
        let patterns: Vec<Vec<u8>> = (0..n_pats)
            .map(|_| {
                if rng.bool() {
                    // sampled from a fragment (must be seedable)
                    let f = rng.below(n_rows);
                    let s = rng.below(frag_chars - pat_chars + 1);
                    fragments[f][s..s + pat_chars].to_vec()
                } else {
                    encode(&rng.dna(pat_chars))
                }
            })
            .collect();
        let rows: Vec<RowAddr> =
            (0..n_rows).map(|i| RowAddr { array: 0, row: i as u32 }).collect();
        let sched = OracularScheduler::build(&fragments, rows, patterns.clone(), k, 32);

        // Soundness: every candidate row shares a k-mer with the pattern.
        for p in &patterns {
            for &r in &sched.candidates(p) {
                let frag = &fragments[r as usize];
                let shares = p
                    .chunks(k)
                    .filter(|w| w.len() == k)
                    .any(|w| frag.windows(k).any(|fw| fw == w));
                assert!(shares, "candidate row {r} shares no seed");
            }
        }

        // Completeness + exclusivity of the packing.
        let passes = sched.schedule(patterns.len());
        let mut scheduled: HashSet<usize> = HashSet::new();
        for pass in &passes {
            let mut rows_used = HashSet::new();
            for &(row, pid) in &pass.assignments {
                assert!(rows_used.insert(row), "row double-booked in a pass");
                scheduled.insert(pid);
            }
        }
        for (pid, p) in patterns.iter().enumerate() {
            if !sched.candidates(p).is_empty() {
                assert!(scheduled.contains(&pid), "seedable pattern {pid} never scheduled");
            }
        }
    }
}

#[test]
fn prop_pass_assignments_subset_of_candidate_set() {
    // Every (row, pattern) assignment the oracular scheduler emits —
    // flat or shard-split — must come from that pattern's k-mer
    // candidate set; the scheduler may drop candidates (caps, packing)
    // but never invent rows.
    let mut rng = Rng::new(0xACED);
    for iter in 0..8 {
        let n_rows = rng.range(8, 48);
        let frag_chars = rng.range(40, 100);
        let pat_chars = rng.range(12, 20);
        let k = rng.range(4, 9);
        let fragments: Vec<Vec<u8>> = (0..n_rows).map(|_| encode(&rng.dna(frag_chars))).collect();
        let patterns: Vec<Vec<u8>> = (0..rng.range(4, 24))
            .map(|_| {
                let f = rng.below(n_rows);
                let s = rng.below(frag_chars - pat_chars + 1);
                fragments[f][s..s + pat_chars].to_vec()
            })
            .collect();
        let rows: Vec<RowAddr> =
            (0..n_rows).map(|i| RowAddr { array: 0, row: i as u32 }).collect();
        let sched = OracularScheduler::build(&fragments, rows, patterns.clone(), k, 24);

        for pass in sched.schedule(patterns.len()) {
            for (row, pid) in pass.assignments {
                assert!(
                    sched.candidates(&patterns[pid]).contains(&row.row),
                    "iter {iter}: pass assignment ({}, {pid}) outside the candidate set",
                    row.row
                );
            }
        }
        // Shard-split emission preserves the same invariant per shard.
        let shard = ShardMap::new(n_rows, 4);
        let linear = |r: RowAddr| r.row as usize;
        for per_shard in sched.schedule_sharded(patterns.len(), &shard, &linear) {
            for (s, pass) in per_shard.iter().enumerate() {
                for &(row, pid) in &pass.assignments {
                    assert_eq!(shard.shard_of(row.row as usize), s, "iter {iter}: shard leak");
                    assert!(
                        sched.candidates(&patterns[pid]).contains(&row.row),
                        "iter {iter}: sharded assignment outside the candidate set"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_multi_lane_results_invariant_random_pools() {
    // Random pool sizes, lane counts and error rates: the coordinator's
    // merged (score, row, loc) answers must not depend on the lane
    // count, and exactly one result per pattern comes back, in order.
    let mut rng = Rng::new(0x1A4E5);
    for iter in 0..6 {
        let n_pats = rng.range(1, 12);
        let ref_chars = 1usize << rng.range(10, 13);
        let lanes = rng.range(2, 6);
        let error_rate = if rng.bool() { 0.05 } else { 0.0 };
        let seed = rng.below(10_000) as u64;
        let w = DnaWorkload::generate(ref_chars, n_pats, 16, error_rate, seed);
        let fragments = w.fragments(64, 16);

        let run_with = |l: usize| {
            let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
            cfg.engine = EngineSpec::Cpu;
            cfg.oracular = Some((8, 16));
            cfg.lanes = l;
            Coordinator::new(cfg, fragments.clone()).unwrap().run(&w.patterns).unwrap().0
        };
        let single = run_with(1);
        let multi = run_with(lanes);
        assert_eq!(single.len(), n_pats, "iter {iter}");
        assert_eq!(multi.len(), n_pats, "iter {iter}");
        for (pid, (a, b)) in single.iter().zip(&multi).enumerate() {
            assert_eq!(a.pattern_id, pid, "iter {iter}: results out of order");
            assert_eq!(b.pattern_id, pid, "iter {iter}: results out of order");
            assert_eq!(
                a.best.map(|x| (x.score, x.row, x.loc)),
                b.best.map(|x| (x.score, x.row, x.loc)),
                "iter {iter}: lanes={lanes} diverged on pattern {pid}"
            );
        }
    }
}

/// Fresh-everything bitsim reference: new array, re-lowered programs,
/// allocating read-outs — the pre-cache/pre-pool path, reproduced via
/// the public API. Returns the merged best as `(score, row, loc)`.
#[allow(clippy::too_many_arguments)]
fn fresh_bitsim_best(
    frag_chars: usize,
    pat_chars: usize,
    mode: PresetMode,
    rows_per_block: usize,
    fragments: &[Vec<u8>],
    row_ids: &[u32],
    pattern: &[u8],
) -> Option<(usize, usize, usize)> {
    let layout = sized_layout(frag_chars, pat_chars, mode);
    let mut best: Option<(usize, usize, usize)> = None;
    for (bi, block) in fragments.chunks(rows_per_block).enumerate() {
        let rows = block.len();
        let mut arr = CramArray::new(rows, layout.total_cols());
        for (r, f) in block.iter().enumerate() {
            arr.write_encoded(r, layout.frag_col() as usize, &Encoded { codes: f.clone() });
        }
        arr.broadcast_encoded(layout.pat_col() as usize, &Encoded { codes: pattern.to_vec() });
        let mut cg = CodeGen::new(layout, mode);
        let mut row_best = vec![(0u64, 0usize); rows];
        for loc in 0..layout.n_alignments() as u32 {
            let out = arr.execute(&cg.alignment_program(loc, true)).unwrap();
            for (r, &s) in out.scores[0].iter().enumerate() {
                if s > row_best[r].0 {
                    row_best[r] = (s, loc as usize);
                }
            }
        }
        for (r, &(s, loc)) in row_best.iter().enumerate() {
            let rid = row_ids[bi * rows_per_block + r] as usize;
            if best.map_or(true, |(bs, _, _)| (s as usize) > bs) {
                best = Some((s as usize, rid, loc));
            }
        }
    }
    best
}

/// The tentpole invariant, engine level: cached programs + pooled
/// array/buffers are bit-identical to a fresh-everything run — across
/// both preset modes, row counts straddling the 64-bit word boundary,
/// and block splits (the pooled array is reset-and-refilled between
/// blocks of different heights). The engine instance is reused across
/// row counts, so pooled state must also not leak between items.
#[test]
fn prop_cached_pooled_bitsim_equals_fresh_everything() {
    use cram_pm::coordinator::{BitsimEngine, Engine, WorkItem};
    use std::sync::Arc;
    let mut rng = Rng::new(0x90013D);
    let (frag_chars, pat_chars) = (24usize, 6usize);
    for mode in [PresetMode::Standard, PresetMode::Gang] {
        for rows_per_block in [64usize, 130] {
            let mut engine = BitsimEngine::new(frag_chars, pat_chars, rows_per_block, mode).unwrap();
            for n_rows in [63usize, 64, 65, 130] {
                let fragments: Vec<Vec<u8>> =
                    (0..n_rows).map(|_| encode(&rng.dna(frag_chars))).collect();
                // Pattern planted in a random row so ties and real hits
                // both occur.
                let home = rng.below(n_rows);
                let start = rng.below(frag_chars - pat_chars + 1);
                let pattern = fragments[home][start..start + pat_chars].to_vec();
                let row_ids: Vec<u32> = (0..n_rows as u32).collect();

                let want = fresh_bitsim_best(
                    frag_chars,
                    pat_chars,
                    mode,
                    rows_per_block,
                    &fragments,
                    &row_ids,
                    &pattern,
                );
                let item = WorkItem {
                    pattern_id: 0,
                    alphabet: cram_pm::alphabet::Alphabet::Dna2,
                    semantics: cram_pm::semantics::MatchSemantics::BestOf,
                    pattern: Arc::from(pattern.as_slice()),
                    fragments: fragments
                        .iter()
                        .map(|f| Arc::from(f.as_slice()))
                        .collect(),
                    row_ids,
                };
                let got = engine.run(&item).unwrap();
                assert_eq!(
                    got.best.map(|b| (b.score, b.row, b.loc)),
                    want,
                    "{mode:?} rows_per_block={rows_per_block} n_rows={n_rows}"
                );
                assert_eq!(got.passes, n_rows.div_ceil(rows_per_block));
            }
        }
    }
}

/// The tentpole invariant, coordinator level: with the bit-level
/// engine behind 1–4 executor lanes (each lane sharing one compiled
/// program cache), merged results are bit-identical to single-lane —
/// for both preset modes, both routing modes, and substrate heights
/// that straddle the 64-bit word boundary.
#[test]
fn prop_bitsim_coordinator_lane_count_invariant() {
    let mut rng = Rng::new(0x1A9E5B);
    for &n_frags in &[63usize, 65, 130] {
        let fragments: Vec<Vec<u8>> = (0..n_frags).map(|_| encode(&rng.dna(64))).collect();
        let patterns: Vec<Vec<u8>> = (0..4)
            .map(|_| {
                let f = rng.below(n_frags);
                let s = rng.below(64 - 16 + 1);
                fragments[f][s..s + 16].to_vec()
            })
            .collect();
        for mode in [PresetMode::Standard, PresetMode::Gang] {
            for oracular in [None, Some((8usize, 32usize))] {
                let run_with = |lanes: usize| {
                    let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
                    cfg.engine = EngineSpec::Bitsim;
                    cfg.preset_mode = mode;
                    cfg.oracular = oracular;
                    cfg.lanes = lanes;
                    Coordinator::new(cfg, fragments.clone())
                        .unwrap()
                        .run(&patterns)
                        .unwrap()
                        .0
                };
                let single = run_with(1);
                for lanes in [2usize, 3, 4] {
                    let multi = run_with(lanes);
                    assert_eq!(single.len(), multi.len());
                    for (a, b) in single.iter().zip(&multi) {
                        assert_eq!(
                            a.best.map(|x| (x.score, x.row, x.loc)),
                            b.best.map(|x| (x.score, x.row, x.loc)),
                            "n_frags={n_frags} {mode:?} lanes={lanes} \
                             oracular={oracular:?} pattern {}",
                            a.pattern_id
                        );
                    }
                }
            }
        }
    }
}

/// The packed CPU scorer is bit-identical to the score-profile scan it
/// replaced, across random geometries straddling the 32-char packing
/// word boundary.
#[test]
fn prop_packed_scorer_equals_profile_scan() {
    use cram_pm::dna::{packed_best_alignment, Packed2};
    let mut rng = Rng::new(0x5C0);
    for iter in 0..60 {
        let pat_chars = rng.range(1, 70);
        let frag_chars = pat_chars + rng.range(0, 80);
        let frag = encode(&rng.dna(frag_chars));
        let pat = if rng.bool() {
            // planted: real high-score alignments
            let s = rng.below(frag_chars - pat_chars + 1);
            frag[s..s + pat_chars].to_vec()
        } else {
            encode(&rng.dna(pat_chars))
        };
        let mut want: Option<(usize, usize)> = None;
        for (loc, &s) in score_profile(&frag, &pat).iter().enumerate() {
            if want.map_or(true, |(bs, _)| s > bs) {
                want = Some((s, loc));
            }
        }
        let got = packed_best_alignment(&Packed2::from_codes(&frag), &Packed2::from_codes(&pat));
        assert_eq!(got, want, "iter {iter} frag={frag_chars} pat={pat_chars}");
    }
}

/// Satellite: alphabet round-trips — encode∘decode is the identity on
/// valid text, decode∘encode is the identity on valid codes, for all
/// three alphabets, at lengths straddling the packing word boundaries.
#[test]
fn prop_alphabet_roundtrips() {
    use cram_pm::alphabet::Alphabet;
    let mut rng = Rng::new(0xA1B2);
    for alphabet in Alphabet::ALL {
        for len in [0usize, 1, 63, 64, 65, 130] {
            let codes = alphabet.random_codes(&mut rng, len);
            assert!(alphabet.codes_valid(&codes), "{alphabet} len={len}");
            let text = alphabet.decode(&codes);
            assert_eq!(alphabet.encode(&text), codes, "{alphabet} len={len}");
        }
    }
}

/// Satellite: the width-generic packed scorer is bit-identical to the
/// scalar `score_profile` scan for all three alphabets, across the
/// 63/64/65-character word boundaries (and each alphabet's own
/// chars-per-word boundary), on planted and random patterns.
#[test]
fn prop_generic_packed_scorer_equals_profile_scan_all_alphabets() {
    use cram_pm::alphabet::{packed_best_alignment, Alphabet, PackedSeq};
    let mut rng = Rng::new(0x6E4E51C);
    for alphabet in Alphabet::ALL {
        let step = alphabet.chars_per_word();
        let frag_lens = [63usize, 64, 65, step, step + 1, 130];
        for (iter, &frag_chars) in frag_lens.iter().enumerate() {
            for planted in [false, true] {
                let pat_chars = 1 + rng.below(frag_chars.min(70));
                let frag = alphabet.random_codes(&mut rng, frag_chars);
                let pat = if planted {
                    let s = rng.below(frag_chars - pat_chars + 1);
                    frag[s..s + pat_chars].to_vec()
                } else {
                    alphabet.random_codes(&mut rng, pat_chars)
                };
                let mut want: Option<(usize, usize)> = None;
                for (loc, &s) in score_profile(&frag, &pat).iter().enumerate() {
                    if want.map_or(true, |(bs, _)| s > bs) {
                        want = Some((s, loc));
                    }
                }
                let got = packed_best_alignment(
                    &PackedSeq::from_codes(alphabet, &frag),
                    &PackedSeq::from_codes(alphabet, &pat),
                );
                assert_eq!(
                    got, want,
                    "{alphabet} iter={iter} frag={frag_chars} pat={pat_chars} planted={planted}"
                );
            }
        }
    }
}

/// Satellite: DNA results are unchanged by the generalization — the
/// generic scorer at `Dna2` answers exactly what `Packed2` answers
/// (which `prop_packed_scorer_equals_profile_scan` in turn pins to the
/// pre-refactor profile scan).
#[test]
fn prop_generic_scorer_dna_identical_to_packed2() {
    use cram_pm::alphabet::{packed_best_alignment, Alphabet, PackedSeq};
    use cram_pm::dna::{packed_best_alignment as p2_best, Packed2};
    let mut rng = Rng::new(0xD2A2);
    for _ in 0..40 {
        let pat_chars = rng.range(1, 70);
        let frag_chars = pat_chars + rng.range(0, 80);
        let frag = encode(&rng.dna(frag_chars));
        let pat = encode(&rng.dna(pat_chars));
        let generic = packed_best_alignment(
            &PackedSeq::from_codes(Alphabet::Dna2, &frag),
            &PackedSeq::from_codes(Alphabet::Dna2, &pat),
        );
        let dna = p2_best(&Packed2::from_codes(&frag), &Packed2::from_codes(&pat));
        assert_eq!(generic, dna, "frag={frag_chars} pat={pat_chars}");
    }
}

/// Satellite + tentpole: the gate-level array executing the
/// width-generic Algorithm 1 lowering equals the character-level
/// oracle for every alphabet, random geometries, both preset modes.
#[test]
fn prop_bitsim_generic_alphabets_equal_oracle() {
    use cram_pm::alphabet::Alphabet;
    use cram_pm::isa::ProgramCache;
    let mut rng = Rng::new(0x5EED5);
    for alphabet in Alphabet::ALL {
        for iter in 0..6 {
            let pat_chars = rng.range(1, 10);
            let frag_chars = pat_chars + rng.range(0, 24);
            let rows = rng.range(1, 70);
            let mode = if rng.bool() { PresetMode::Gang } else { PresetMode::Standard };
            let cache =
                ProgramCache::for_alphabet(alphabet, frag_chars, pat_chars, mode, true).unwrap();
            let layout = *cache.layout();

            let fragments: Vec<Vec<u8>> =
                (0..rows).map(|_| alphabet.random_codes(&mut rng, frag_chars)).collect();
            let pattern = alphabet.random_codes(&mut rng, pat_chars);

            let mut arr = CramArray::new(rows, layout.total_cols());
            for (r, f) in fragments.iter().enumerate() {
                arr.write_codes_bits(r, layout.frag_col() as usize, f, layout.bits_per_char);
            }
            arr.broadcast_codes_bits(layout.pat_col() as usize, &pattern, layout.bits_per_char);

            for _ in 0..3.min(layout.n_alignments()) {
                let loc = rng.below(layout.n_alignments()) as u32;
                let out = arr.execute(cache.program(loc)).unwrap();
                for (r, f) in fragments.iter().enumerate() {
                    let want = score_profile(f, &pattern)[loc as usize] as u64;
                    assert_eq!(
                        out.scores[0][r], want,
                        "{alphabet} iter={iter} {mode:?} frag={frag_chars} pat={pat_chars} \
                         rows={rows} loc={loc} row {r}"
                    );
                }
            }
        }
    }
}

/// Tentpole acceptance: `Threshold` / `TopK` hit lists are equal to
/// the scalar reference oracle across word-boundary row counts
/// (63/64/65) for **both** the bitsim and CPU engines, at every
/// alphabet — and `best` stays equal to `reference_best` under every
/// semantics (including `BestOf`, whose hit list is empty).
#[test]
fn prop_hit_enumeration_equals_scalar_oracle_both_engines() {
    use cram_pm::alphabet::Alphabet;
    use cram_pm::bench_apps::{reference_best, reference_hits};
    use cram_pm::coordinator::{BitsimEngine, CpuEngine, Engine, WorkItem};
    use cram_pm::semantics::MatchSemantics;
    use std::sync::Arc;
    let mut rng = Rng::new(0x4117);
    let (frag_chars, pat_chars) = (24usize, 6usize);
    for alphabet in Alphabet::ALL {
        let mut cpu = CpuEngine::new(alphabet);
        // rows_per_block 64: the 65-row item splits across two blocks,
        // so block-boundary reassembly of hit lists is exercised.
        let mut bitsim =
            BitsimEngine::new_alphabet(alphabet, frag_chars, pat_chars, 64, PresetMode::Gang)
                .unwrap();
        for n_rows in [63usize, 64, 65] {
            let fragments: Vec<Vec<u8>> =
                (0..n_rows).map(|_| alphabet.random_codes(&mut rng, frag_chars)).collect();
            let home = rng.below(n_rows);
            let start = rng.below(frag_chars - pat_chars + 1);
            let pattern = fragments[home][start..start + pat_chars].to_vec();
            for semantics in [
                MatchSemantics::BestOf,
                MatchSemantics::Threshold { min_score: 4 },
                MatchSemantics::TopK { k: 7 },
            ] {
                let item = WorkItem {
                    pattern_id: 0,
                    alphabet,
                    semantics,
                    pattern: Arc::from(pattern.as_slice()),
                    fragments: fragments.iter().map(|f| Arc::from(f.as_slice())).collect(),
                    row_ids: (0..n_rows as u32).collect(),
                };
                let want_hits = reference_hits(&fragments, &pattern, semantics);
                let want_best = reference_best(&fragments, &pattern);
                if semantics.enumerates() {
                    assert!(!want_hits.is_empty(), "planted pattern must hit the oracle");
                }
                let from_cpu = cpu.run(&item).unwrap();
                let from_bitsim = bitsim.run(&item).unwrap();
                for (label, got) in [("cpu", &from_cpu), ("bitsim", &from_bitsim)] {
                    assert_eq!(
                        got.hits, want_hits,
                        "{alphabet} rows={n_rows} {semantics} {label}: hit list diverged"
                    );
                    assert_eq!(
                        got.best.map(|b| (b.score, b.row, b.loc)),
                        want_best,
                        "{alphabet} rows={n_rows} {semantics} {label}: best diverged"
                    );
                }
            }
        }
    }
}

/// Satellite: the static dataflow optimizer is invisible end-to-end.
/// An `O1` coordinator (optimized alignment programs) answers every
/// query bit-identically to `O0` (raw codegen output) — best tuples,
/// full hit lists, and the countable metrics shape — for both device
/// engines, every alphabet, all three semantics, 1–4 executor lanes,
/// and substrate heights straddling the 64-row word boundary. The CPU
/// engine has no compiled cache, so its pair doubles as a check that
/// `opt_level` is inert where it should be.
#[test]
fn prop_optimized_programs_bit_identical_end_to_end() {
    use cram_pm::alphabet::Alphabet;
    use cram_pm::isa::OptLevel;
    use cram_pm::semantics::MatchSemantics;
    let mut rng = Rng::new(0x0715CA7);
    let (frag_chars, pat_chars) = (24usize, 6usize);
    let semantics_pool = [
        MatchSemantics::BestOf,
        MatchSemantics::Threshold { min_score: 4 },
        MatchSemantics::TopK { k: 5 },
    ];
    for engine in [EngineSpec::Cpu, EngineSpec::Bitsim] {
        for alphabet in Alphabet::ALL {
            for (row_case, n_frags) in [63usize, 64, 65].into_iter().enumerate() {
                let fragments: Vec<Vec<u8>> =
                    (0..n_frags).map(|_| alphabet.random_codes(&mut rng, frag_chars)).collect();
                let home = rng.below(n_frags);
                let start = rng.below(frag_chars - pat_chars + 1);
                let patterns: Vec<Vec<u8>> = vec![
                    fragments[home][start..start + pat_chars].to_vec(),
                    alphabet.random_codes(&mut rng, pat_chars),
                ];
                for lanes in 1usize..=4 {
                    // Cycle the semantics against the lane count so every
                    // (lanes, semantics) pairing appears across the sweep
                    // without cubing the matrix.
                    let semantics = semantics_pool[(lanes + row_case) % semantics_pool.len()];
                    let run_at = |opt_level: OptLevel| {
                        let mut cfg = CoordinatorConfig::for_alphabet(
                            alphabet,
                            engine.clone(),
                            frag_chars,
                            pat_chars,
                        );
                        cfg.semantics = semantics;
                        cfg.oracular = None;
                        cfg.lanes = lanes;
                        cfg.opt_level = opt_level;
                        Coordinator::new(cfg, fragments.clone()).unwrap().run(&patterns).unwrap()
                    };
                    let (r0, m0) = run_at(OptLevel::O0);
                    let (r1, m1) = run_at(OptLevel::O1);
                    let ctx =
                        format!("{engine} {alphabet} rows={n_frags} lanes={lanes} {semantics}");
                    assert_eq!(r0.len(), r1.len(), "{ctx}: result count diverged");
                    for (a, b) in r0.iter().zip(&r1) {
                        assert_eq!(a.pattern_id, b.pattern_id, "{ctx}");
                        assert_eq!(
                            a.best.map(|x| (x.score, x.row, x.loc)),
                            b.best.map(|x| (x.score, x.row, x.loc)),
                            "{ctx} pattern {}: best diverged",
                            a.pattern_id
                        );
                        assert_eq!(
                            a.hits, b.hits,
                            "{ctx} pattern {}: hit list diverged",
                            a.pattern_id
                        );
                    }
                    // The countable metrics shape must match exactly —
                    // O1 changes how many gates a pass executes, never
                    // how many passes, matches, or hits a run reports.
                    assert_eq!(
                        (m0.patterns, m0.matched, m0.hits, m0.passes, &m0.engine, m0.lanes),
                        (m1.patterns, m1.matched, m1.hits, m1.passes, &m1.engine, m1.lanes),
                        "{ctx}: metrics shape diverged"
                    );
                }
            }
        }
    }
}

/// Tentpole: the CPU engine's SIMD block path is bit-identical to the
/// scalar oracle for every kernel available on this host — every
/// alphabet, fragment lengths straddling the 64- and 128-char word
/// boundaries, planted patterns, and all three match semantics (so hit
/// lists and pass counts are diffed too, not just the best tuple).
/// Under a forced `CRAM_PM_SIMD` this suite still covers every
/// *compiled* kernel: `with_kernel` bypasses the process-wide dispatch.
#[test]
fn prop_simd_scorer_equals_scalar_every_width() {
    use cram_pm::alphabet::Alphabet;
    use cram_pm::coordinator::{CpuEngine, Engine, SimdKernel, WorkItem};
    use cram_pm::semantics::MatchSemantics;
    use std::sync::Arc;
    let mut rng = Rng::new(0x51DCAFE);
    let kernels = SimdKernel::all_available();
    for alphabet in Alphabet::ALL {
        let mut oracle = CpuEngine::with_kernel(alphabet, SimdKernel::Scalar);
        let mut engines: Vec<CpuEngine> =
            kernels.iter().map(|&k| CpuEngine::with_kernel(alphabet, k)).collect();
        for frag_chars in [63usize, 64, 65, 127, 128, 129] {
            let n_rows = rng.range(1, 70);
            let pat_chars = 1 + rng.below(frag_chars.min(40));
            let fragments: Vec<Vec<u8>> =
                (0..n_rows).map(|_| alphabet.random_codes(&mut rng, frag_chars)).collect();
            let home = rng.below(n_rows);
            let start = rng.below(frag_chars - pat_chars + 1);
            let pattern = fragments[home][start..start + pat_chars].to_vec();
            for semantics in [
                MatchSemantics::BestOf,
                MatchSemantics::Threshold { min_score: pat_chars.saturating_sub(1) },
                MatchSemantics::TopK { k: 5 },
            ] {
                let item = WorkItem {
                    pattern_id: 0,
                    alphabet,
                    semantics,
                    pattern: Arc::from(pattern.as_slice()),
                    fragments: fragments.iter().map(|f| Arc::from(f.as_slice())).collect(),
                    row_ids: (0..n_rows as u32).collect(),
                };
                let want = oracle.run(&item).unwrap();
                for (eng, &kernel) in engines.iter_mut().zip(&kernels) {
                    let got = eng.run(&item).unwrap();
                    let ctx = format!(
                        "{alphabet} kernel={kernel} frag={frag_chars} pat={pat_chars} \
                         rows={n_rows} {semantics}"
                    );
                    assert_eq!(got.best, want.best, "{ctx}: best diverged");
                    assert_eq!(got.hits, want.hits, "{ctx}: hit list diverged");
                    assert_eq!(got.passes, want.passes, "{ctx}: pass count diverged");
                }
            }
        }
    }
}

/// Tentpole: the bitsim word-op kernels (bit-sliced gate apply, bulk
/// block staging via `write_codes_rows`, word-transposed readout) are
/// bit-identical across every available kernel — proven end to end by
/// executing compiled Algorithm 1 programs on kernel-forced arrays and
/// pinning every kernel's scores to the character-level oracle.
#[test]
fn prop_simd_bitsim_word_ops_equal_scalar() {
    use cram_pm::alphabet::Alphabet;
    use cram_pm::coordinator::SimdKernel;
    use cram_pm::isa::ProgramCache;
    let mut rng = Rng::new(0xB1751D);
    let kernels = SimdKernel::all_available();
    for alphabet in Alphabet::ALL {
        for &rows in &[63usize, 64, 65, 129] {
            let pat_chars = rng.range(2, 8);
            let frag_chars = pat_chars + rng.range(0, 20);
            let cache =
                ProgramCache::for_alphabet(alphabet, frag_chars, pat_chars, PresetMode::Gang, true)
                    .unwrap();
            let layout = *cache.layout();
            let fragments: Vec<Vec<u8>> =
                (0..rows).map(|_| alphabet.random_codes(&mut rng, frag_chars)).collect();
            let pattern = alphabet.random_codes(&mut rng, pat_chars);
            let loc = rng.below(layout.n_alignments()) as u32;
            let want: Vec<u64> = fragments
                .iter()
                .map(|f| score_profile(f, &pattern)[loc as usize] as u64)
                .collect();
            for &kernel in &kernels {
                let mut arr = CramArray::with_kernel(rows, layout.total_cols(), kernel);
                arr.write_codes_rows(layout.frag_col() as usize, &fragments, layout.bits_per_char);
                arr.broadcast_codes_bits(layout.pat_col() as usize, &pattern, layout.bits_per_char);
                let out = arr.execute(cache.program(loc)).unwrap();
                assert_eq!(
                    out.scores[0], want,
                    "{alphabet} kernel={kernel} rows={rows} frag={frag_chars} \
                     pat={pat_chars} loc={loc}"
                );
            }
        }
    }
}

/// Satellite: forcing the coordinator's dispatch
/// ([`CoordinatorConfig::simd`]) to any available kernel yields
/// results bit-identical to forcing the scalar oracle — including
/// enumerated hit lists under `TopK` — and the run's metrics report
/// the forced kernel's tag.
#[test]
fn prop_coordinator_forced_dispatch_invariant() {
    use cram_pm::coordinator::SimdKernel;
    use cram_pm::semantics::MatchSemantics;
    let w = DnaWorkload::generate(1 << 12, 8, 16, 0.02, 17);
    let fragments = w.fragments(64, 16);
    let run_with = |kernel: SimdKernel| {
        let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
        cfg.engine = EngineSpec::Cpu;
        cfg.semantics = MatchSemantics::TopK { k: 4 };
        cfg.oracular = None;
        cfg.lanes = 2;
        cfg.simd = Some(kernel);
        Coordinator::new(cfg, fragments.clone()).unwrap().run(&w.patterns).unwrap()
    };
    let (want, want_metrics) = run_with(SimdKernel::Scalar);
    assert_eq!(want_metrics.simd, "scalar", "forced scalar must be reported");
    for kernel in SimdKernel::all_available() {
        let (got, metrics) = run_with(kernel);
        assert_eq!(metrics.simd, kernel.tag(), "metrics must name the forced kernel");
        assert_eq!(got.len(), want.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.best, b.best, "kernel {kernel} pattern {}: best diverged", a.pattern_id);
            assert_eq!(a.hits, b.hits, "kernel {kernel} pattern {}: hits diverged", a.pattern_id);
        }
    }
}

#[test]
fn prop_bitsim_gate_zoo_random_states() {
    // Every gate kind, random input columns and row counts: the
    // bit-sliced implementation equals per-row scalar evaluation.
    let mut rng = Rng::new(0xF00D);
    for kind in cram_pm::gates::GateKind::ALL {
        for _ in 0..6 {
            let rows = rng.range(1, 200);
            let n = kind.n_inputs();
            let mut arr = CramArray::new(rows, n + 1);
            for c in 0..n {
                for r in 0..rows {
                    arr.set(r, c, rng.bool());
                }
            }
            let ins: Vec<u32> = (0..n as u32).collect();
            let mut prog = cram_pm::isa::Program::new();
            prog.push(
                cram_pm::isa::Stage::Match,
                MicroInstr::gate(kind, n as u32, &ins),
            );
            arr.execute(&prog).unwrap();
            for r in 0..rows {
                let inputs: Vec<bool> = (0..n).map(|c| arr.get(r, c)).collect();
                assert_eq!(arr.get(r, n), kind.eval(&inputs), "{kind} row {r} rows={rows}");
            }
        }
    }
}

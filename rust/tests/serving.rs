//! Serving-layer integration tests: concurrent clients through the
//! `MatchServer` must get bit-identical answers to direct
//! `Coordinator::run` calls (batching and dedup must not change
//! tie-breaking), backpressure must reject-and-recover, and shutdown
//! must drain every accepted request.

use cram_pm::alphabet::{Alphabet, CodedWorkload};
use cram_pm::bench_apps::dna::DnaWorkload;
use cram_pm::bench_apps::{reference_best, reference_hits};
use cram_pm::coordinator::{Coordinator, CoordinatorConfig, EngineSpec};
use cram_pm::semantics::MatchSemantics;
use cram_pm::serve::{Backpressure, MatchRequest, MatchServer, ServeConfig, ServeError};
use cram_pm::util::Rng;
use std::sync::Arc;
use std::time::Duration;

/// Coordinator over an erroneous-read workload (ties and near-ties are
/// common, so tie-breaking is actually exercised) plus its catalog.
fn coordinator(lanes: usize, seed: u64, catalog: usize) -> (Arc<Coordinator>, Vec<Vec<u8>>) {
    let w = DnaWorkload::generate(4096, catalog, 16, 0.05, seed);
    let fragments = w.fragments(64, 16);
    let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    cfg.engine = EngineSpec::Cpu;
    cfg.lanes = lanes;
    (Arc::new(Coordinator::new(cfg, fragments).unwrap()), w.patterns)
}

fn serve_cfg(max_batch: usize, dedup: bool) -> ServeConfig {
    ServeConfig {
        max_batch,
        max_delay: Duration::from_millis(2),
        queue_depth: 64,
        backpressure: Backpressure::Block,
        dedup,
        max_hits: 4096,
        deadline: None,
    }
}

/// The keystone property: N concurrent clients submitting pools with
/// heavy duplication get, per request, exactly what a direct
/// `Coordinator::run` of the same pool returns — same (score, row,
/// loc), same order — with dedup on and off.
#[test]
fn prop_concurrent_clients_bit_identical_to_direct_runs() {
    let (coordinator, catalog) = coordinator(3, 11, 48);
    for dedup in [true, false] {
        let server = MatchServer::start(Arc::clone(&coordinator), serve_cfg(32, dedup)).unwrap();
        std::thread::scope(|scope| {
            for cid in 0..4u64 {
                let server = &server;
                let coordinator = &coordinator;
                let catalog = &catalog;
                scope.spawn(move || {
                    let mut rng = Rng::new(1000 + cid);
                    for _ in 0..8 {
                        // Duplicates within and across requests are
                        // likely: draws come from a 48-pattern catalog.
                        let pool: Vec<Vec<u8>> = (0..rng.range(1, 7))
                            .map(|_| catalog[rng.below(catalog.len())].clone())
                            .collect();
                        let resp = server.match_patterns(pool.clone()).unwrap();
                        let (direct, _) = coordinator.run(&pool).unwrap();
                        assert_eq!(resp.results.len(), direct.len());
                        for (a, b) in resp.results.iter().zip(&direct) {
                            assert_eq!(a.pattern_id, b.pattern_id);
                            assert_eq!(
                                a.best.map(|x| (x.score, x.row, x.loc)),
                                b.best.map(|x| (x.score, x.row, x.loc)),
                                "dedup={dedup} client {cid} pattern {}",
                                a.pattern_id
                            );
                        }
                    }
                });
            }
        });
        let totals = server.shutdown();
        assert_eq!(totals.requests, 4 * 8, "dedup={dedup}: lost requests");
    }
}

/// Reject backpressure: a submission storm against a 1-deep admission
/// queue must shed load with `Overloaded`, every *admitted* request
/// must still be answered, and a retry after the storm succeeds.
#[test]
fn reject_backpressure_sheds_then_recovers() {
    let (coordinator, catalog) = coordinator(2, 21, 32);
    let server = MatchServer::start(
        coordinator,
        ServeConfig {
            max_batch: 4,
            max_delay: Duration::from_micros(100),
            queue_depth: 1,
            backpressure: Backpressure::Reject,
            dedup: true,
            max_hits: 4096,
            deadline: None,
        },
    )
    .unwrap();
    let mut pending = Vec::new();
    let mut rejected = 0usize;
    for i in 0..400 {
        match server.submit(vec![catalog[i % catalog.len()].clone(); 4]) {
            Ok(p) => pending.push(p),
            Err(ServeError::Overloaded) => rejected += 1,
            Err(e) => panic!("unexpected serve error: {e}"),
        }
    }
    assert!(rejected > 0, "storm never hit the bounded admission queue");
    assert!(!pending.is_empty(), "every request was rejected");
    for p in pending {
        let resp = p.wait().expect("admitted request must be served");
        assert_eq!(resp.results.len(), 4);
    }
    // Reject-with-retry: once the storm passes, admission succeeds.
    let retried = server.match_patterns(vec![catalog[0].clone()]).unwrap();
    assert_eq!(retried.results.len(), 1);
    let totals = server.shutdown();
    assert_eq!(totals.rejected, rejected, "server under-counted rejections");
}

/// Block backpressure never refuses: the same storm pattern completes
/// with zero rejections (callers park on the bounded queue instead).
#[test]
fn block_backpressure_never_rejects() {
    let (coordinator, catalog) = coordinator(2, 51, 16);
    let server = MatchServer::start(
        coordinator,
        ServeConfig {
            max_batch: 8,
            max_delay: Duration::from_micros(200),
            queue_depth: 2,
            backpressure: Backpressure::Block,
            dedup: true,
            max_hits: 4096,
            deadline: None,
        },
    )
    .unwrap();
    std::thread::scope(|scope| {
        for cid in 0..4usize {
            let server = &server;
            let catalog = &catalog;
            scope.spawn(move || {
                for i in 0..25 {
                    let pool = vec![catalog[(cid + i) % catalog.len()].clone(); 2];
                    server.match_patterns(pool).unwrap();
                }
            });
        }
    });
    let totals = server.shutdown();
    assert_eq!(totals.rejected, 0);
    assert_eq!(totals.requests, 100);
}

/// Graceful drain: requests queued at shutdown are all answered before
/// the batcher exits; none are dropped.
#[test]
fn shutdown_drains_queued_and_inflight_requests() {
    let (coordinator, catalog) = coordinator(2, 31, 16);
    let server = MatchServer::start(
        coordinator,
        ServeConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(5),
            queue_depth: 32,
            backpressure: Backpressure::Block,
            dedup: true,
            max_hits: 4096,
            deadline: None,
        },
    )
    .unwrap();
    let pending: Vec<_> = (0..20)
        .map(|i| server.submit(vec![catalog[i % catalog.len()].clone(); 2]).unwrap())
        .collect();
    // Shutdown immediately: most of those requests are still queued.
    let totals = server.shutdown();
    assert_eq!(totals.requests, 20, "shutdown dropped queued requests");
    for p in pending {
        let resp = p.wait().expect("drained request must still be answered");
        assert_eq!(resp.results.len(), 2);
    }
}

/// Acceptance criterion: an ASCII StringMatch pool and a protein pool
/// run end-to-end through `MatchServer` — concurrent tagged clients,
/// batching and dedup on — and every answer is bit-identical to the
/// scalar reference scorer over the resident rows.
#[test]
fn ascii_and_protein_pools_serve_end_to_end_matching_scalar_reference() {
    for alphabet in [Alphabet::Ascii8, Alphabet::Protein5] {
        let w = CodedWorkload::generate(alphabet, 4096, 32, 16, 0.05, 42);
        let fragments = w.fragments(64, 16);
        let mut cfg = CoordinatorConfig::for_alphabet(alphabet, EngineSpec::Cpu, 64, 16);
        cfg.oracular = None; // broadcast: the reference scans every row
        cfg.lanes = 3;
        let coordinator = Arc::new(Coordinator::new(cfg, fragments.clone()).unwrap());
        let server = MatchServer::start(Arc::clone(&coordinator), serve_cfg(32, true)).unwrap();
        std::thread::scope(|scope| {
            for cid in 0..3u64 {
                let server = &server;
                let catalog = &w.patterns;
                let fragments = &fragments;
                scope.spawn(move || {
                    let mut rng = Rng::new(900 + cid);
                    for _ in 0..4 {
                        let pool: Vec<Vec<u8>> = (0..rng.range(1, 5))
                            .map(|_| catalog[rng.below(catalog.len())].clone())
                            .collect();
                        let resp = server
                            .match_request(MatchRequest::new(alphabet, pool.clone()))
                            .unwrap();
                        assert_eq!(resp.results.len(), pool.len());
                        for (q, r) in pool.iter().zip(&resp.results) {
                            assert_eq!(
                                r.best.map(|b| (b.score, b.row, b.loc)),
                                reference_best(fragments, q),
                                "{alphabet} client {cid}"
                            );
                        }
                    }
                });
            }
        });
        let totals = server.shutdown();
        assert_eq!(totals.requests, 3 * 4, "{alphabet}: lost requests");
    }
}

/// Satellite bugfix regression: a request coded in a different
/// alphabet than the serving coordinator must come back as a typed
/// error — never silently scored at the wrong symbol width. (A 16-code
/// protein pattern has exactly the byte length a DNA server expects,
/// so before the alphabet tag this would have been accepted.)
#[test]
fn mixed_alphabet_batch_refused_with_typed_error() {
    let (coordinator, catalog) = coordinator(2, 91, 8);
    let server = MatchServer::start(coordinator, serve_cfg(16, true)).unwrap();
    let protein_pool = vec![Alphabet::Protein5.encode(b"MKVLAWHEDNCHPRFYQSTG")[..16].to_vec()];
    let err = server
        .submit_request(MatchRequest::new(Alphabet::Protein5, protein_pool))
        .err()
        .expect("cross-alphabet request must be refused");
    assert_eq!(
        err,
        ServeError::AlphabetMismatch { requested: Alphabet::Protein5, serving: Alphabet::Dna2 }
    );
    // Out-of-alphabet codes under the correct tag are refused too.
    let err = server
        .submit_request(MatchRequest::new(Alphabet::Dna2, vec![vec![5u8; 16]]))
        .err()
        .expect("invalid symbols must be refused");
    assert_eq!(err, ServeError::InvalidSymbol { index: 0 });
    // Well-formed traffic is unaffected before and after the refusals.
    let resp = server.match_patterns(vec![catalog[0].clone()]).unwrap();
    assert_eq!(resp.results.len(), 1);
    let totals = server.shutdown();
    assert_eq!(totals.requests, 1, "refused requests must not be counted as served");
}

/// Acceptance criterion (tentpole): `BestOf` results remain
/// bit-identical to the pre-semantics behavior — served answers equal
/// both a direct coordinator run and the scalar reference, with empty
/// hit lists — across 1–4 lanes × dedup on/off × all three alphabets.
#[test]
fn prop_bestof_bit_identical_across_lanes_dedup_and_alphabets() {
    for alphabet in Alphabet::ALL {
        let w = CodedWorkload::generate(alphabet, 2048, 12, 16, 0.05, 77);
        let fragments = w.fragments(64, 16);
        let reference: Vec<_> =
            w.patterns.iter().map(|p| reference_best(&fragments, p)).collect();
        // A duplicate-heavy pool drawn from a small catalog.
        let pool: Vec<Vec<u8>> = (0..10).map(|i| w.patterns[i % 5].clone()).collect();
        for lanes in [1usize, 2, 3, 4] {
            for dedup in [true, false] {
                let mut cfg = CoordinatorConfig::for_alphabet(alphabet, EngineSpec::Cpu, 64, 16);
                cfg.oracular = None; // broadcast: the reference scans every row
                cfg.lanes = lanes;
                assert_eq!(cfg.semantics, MatchSemantics::BestOf, "BestOf must stay the default");
                let coordinator = Arc::new(Coordinator::new(cfg, fragments.clone()).unwrap());
                let server =
                    MatchServer::start(Arc::clone(&coordinator), serve_cfg(16, dedup)).unwrap();
                let resp = server
                    .match_request(MatchRequest::new(alphabet, pool.clone()))
                    .unwrap();
                let (direct, metrics) = coordinator.run(&pool).unwrap();
                assert_eq!(metrics.hits, 0, "{alphabet}: BestOf must enumerate nothing");
                assert_eq!(resp.results.len(), direct.len());
                for ((served, ran), pid) in resp.results.iter().zip(&direct).zip(0..) {
                    let want = reference[pid % 5];
                    assert!(
                        served.hits.is_empty() && ran.hits.is_empty(),
                        "{alphabet} lanes={lanes} dedup={dedup}: BestOf grew hits"
                    );
                    assert_eq!(
                        served.best.map(|b| (b.score, b.row, b.loc)),
                        ran.best.map(|b| (b.score, b.row, b.loc)),
                        "{alphabet} lanes={lanes} dedup={dedup} pattern {pid}"
                    );
                    assert_eq!(
                        served.best.map(|b| (b.score, b.row, b.loc)),
                        want,
                        "{alphabet} lanes={lanes} dedup={dedup} pattern {pid} vs reference"
                    );
                }
                server.shutdown();
            }
        }
    }
}

/// Serving edge path: a single request larger than `max_batch` closes
/// its batch beyond nominal capacity (occupancy > 1.0) and is still
/// answered completely and correctly.
#[test]
fn oversized_single_request_served_with_occupancy_above_one() {
    let (coordinator, catalog) = coordinator(2, 71, 24);
    let server = MatchServer::start(Arc::clone(&coordinator), serve_cfg(4, true)).unwrap();
    let pool: Vec<Vec<u8>> = (0..12).map(|i| catalog[i % catalog.len()].clone()).collect();
    let resp = server.match_patterns(pool.clone()).unwrap();
    assert_eq!(resp.results.len(), 12);
    assert_eq!(resp.batch.patterns, 12);
    assert!(
        resp.batch.occupancy > 1.0,
        "12 offered patterns over max_batch=4 must report occupancy 3.0, got {}",
        resp.batch.occupancy
    );
    let (direct, _) = coordinator.run(&pool).unwrap();
    for (a, b) in resp.results.iter().zip(&direct) {
        assert_eq!(a.best, b.best);
    }
    let totals = server.shutdown();
    assert_eq!(totals.patterns, 12);
    assert!(totals.batches >= 1, "oversized request must still have opened a batch");
}

/// Serving edge path: shutdown drains an in-flight batch carrying
/// `TopK` semantics — every queued request is answered with its full
/// (bounded, best-first) hit list, none dropped.
#[test]
fn shutdown_drains_inflight_topk_batch() {
    let w = DnaWorkload::generate(4096, 16, 16, 0.05, 31);
    let fragments = w.fragments(64, 16);
    let semantics = MatchSemantics::TopK { k: 3 };
    let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    cfg.engine = EngineSpec::Cpu;
    cfg.oracular = None;
    cfg.semantics = semantics;
    cfg.lanes = 2;
    let coordinator = Arc::new(Coordinator::new(cfg, fragments.clone()).unwrap());
    let server = MatchServer::start(
        coordinator,
        ServeConfig {
            max_batch: 8,
            max_delay: Duration::from_millis(5),
            queue_depth: 32,
            backpressure: Backpressure::Block,
            dedup: true,
            max_hits: 4096,
            deadline: None,
        },
    )
    .unwrap();
    let pending: Vec<_> = (0..20)
        .map(|i| server.submit(vec![w.patterns[i % w.patterns.len()].clone(); 2]).unwrap())
        .collect();
    // Shut down immediately: most requests are still queued or mid-batch.
    let totals = server.shutdown();
    assert_eq!(totals.requests, 20, "shutdown dropped queued top-K requests");
    for (i, p) in pending.into_iter().enumerate() {
        let resp = p.wait().expect("drained request must still be answered");
        assert_eq!(resp.results.len(), 2);
        for (r, q) in resp.results.iter().zip([&w.patterns[i % w.patterns.len()]; 2]) {
            assert_eq!(r.hits.len(), 3, "top-3 list expected");
            assert_eq!(r.hits, reference_hits(&fragments, q, semantics));
            let b = r.best.unwrap();
            assert_eq!((r.hits[0].row, r.hits[0].loc, r.hits[0].score), (b.row, b.loc, b.score));
        }
    }
}

/// Dedup accounting reaches the client: a batch of identical patterns
/// reports one unique execution and a matching dedup factor.
#[test]
fn batch_stats_report_dedup_and_occupancy() {
    let (coordinator, catalog) = coordinator(1, 61, 8);
    let server = MatchServer::start(coordinator, serve_cfg(16, true)).unwrap();
    let resp = server.match_patterns(vec![catalog[0].clone(); 6]).unwrap();
    assert_eq!(resp.batch.patterns, 6);
    assert_eq!(resp.batch.unique_patterns, 1);
    assert!((resp.batch.dedup_factor - 6.0).abs() < 1e-9);
    assert!((resp.batch.occupancy - 6.0 / 16.0).abs() < 1e-9);
    assert!(resp.timing.total >= resp.timing.queue_wait + resp.timing.batch_wait);
    server.shutdown();
}

/// End-to-end deadline semantics: a request whose budget expires fails
/// with the typed, retryable `DeadlineExceeded` while its batch-mates
/// are answered normally, and a retry with a sane budget succeeds.
#[test]
fn request_deadline_is_typed_retryable_and_batchmates_complete() {
    let (coordinator, catalog) = coordinator(2, 81, 16);
    let server = MatchServer::start(
        coordinator,
        ServeConfig {
            max_batch: 16,
            max_delay: Duration::from_millis(50),
            queue_depth: 32,
            backpressure: Backpressure::Block,
            dedup: true,
            max_hits: 4096,
            // Server-wide default budget; per-request deadlines below
            // override it.
            deadline: Some(Duration::from_secs(30)),
        },
    )
    .unwrap();
    // The patient request opens the coalescing window; the zero-budget
    // one joins (or trails) it and must expire at dispatch without
    // taking its batch-mates down.
    let patient = server.submit(vec![catalog[0].clone()]).unwrap();
    let doomed = server
        .submit_request(
            MatchRequest::new(Alphabet::Dna2, vec![catalog[1].clone()])
                .with_deadline(Duration::ZERO),
        )
        .unwrap();
    assert_eq!(doomed.wait().err(), Some(ServeError::DeadlineExceeded));
    let resp = patient.wait().expect("batch-mate must still be answered");
    assert_eq!(resp.results.len(), 1);
    // Retrying the failed pattern with a real budget succeeds: the
    // failure is transient, not a property of the pattern.
    let retried = server
        .match_request(
            MatchRequest::new(Alphabet::Dna2, vec![catalog[1].clone()])
                .with_deadline(Duration::from_secs(30)),
        )
        .unwrap();
    assert_eq!(retried.results.len(), 1);
    let totals = server.shutdown();
    assert_eq!(totals.deadline_failures, 1, "exactly one request missed its deadline");
    assert_eq!(totals.requests, 2, "expired requests must not count as served");
}

//! Failure injection: malformed inputs must produce errors, not
//! panics or silent corruption.

use cram_pm::bench_apps::dna::DnaWorkload;
use cram_pm::coordinator::{Coordinator, CoordinatorConfig, CoordinatorError, EngineSpec};
use cram_pm::fault::FaultPlan;
use cram_pm::runtime::{Manifest, Runtime};
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("crampm-fail-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn corrupt_hlo_artifact_is_an_error_not_a_crash() {
    let dir = tmpdir("corrupt");
    std::fs::write(dir.join("manifest.txt"), "bad 256 64 16 bad.hlo.txt\n").unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule this is not hlo {").unwrap();
    let err = Runtime::load(&dir);
    assert!(err.is_err(), "corrupt artifact must fail to load");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_artifact_file_is_an_error() {
    let dir = tmpdir("missing");
    std::fs::write(dir.join("manifest.txt"), "ghost 256 64 16 ghost.hlo.txt\n").unwrap();
    assert!(Runtime::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_with_zero_rows_rejected() {
    let dir = tmpdir("zerorows");
    std::fs::write(dir.join("manifest.txt"), "z 0 64 16 z.hlo.txt\n").unwrap();
    assert!(Manifest::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn coordinator_rejects_ragged_fragments() {
    let cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    let mut frags = vec![vec![0u8; 64]; 4];
    frags[2].pop();
    assert!(Coordinator::new(cfg, frags).is_err());
}

#[test]
fn coordinator_rejects_empty_fragment_set() {
    let cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    assert!(Coordinator::new(cfg, vec![]).is_err());
}

#[test]
fn xla_engine_surfaces_missing_artifacts_from_new() {
    // The startup handshake: engine construction failures inside the
    // executor lanes must fail `Coordinator::new`, not the first run.
    let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    cfg.engine = EngineSpec::xla("dna_small", "/nonexistent/artifacts");
    let res = Coordinator::new(cfg, vec![vec![0u8; 64]; 4]);
    let err = res.err().expect("missing artifacts must fail the startup handshake");
    let msg = format!("{err:#}");
    assert!(msg.contains("artifacts") || msg.contains("XLA"), "unhelpful error: {msg}");
}

#[test]
fn broken_engine_fails_construction_for_every_lane_count() {
    for lanes in [1usize, 2, 4] {
        let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
        cfg.engine = EngineSpec::xla("dna_small", "/nonexistent/artifacts");
        cfg.lanes = lanes;
        assert!(
            Coordinator::new(cfg, vec![vec![0u8; 64]; 8]).is_err(),
            "lanes={lanes}: broken engine must fail new()"
        );
    }
}

#[test]
fn empty_pattern_slice_short_circuits_cleanly() {
    // The bugfix: an empty pool must not fall through the lane
    // machinery — it returns an empty result with zeroed metrics.
    let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    cfg.engine = EngineSpec::Cpu;
    cfg.lanes = 3;
    let coord = Coordinator::new(cfg, vec![vec![0u8; 64]; 6]).unwrap();
    let (results, m) = coord.run(&[]).unwrap();
    assert!(results.is_empty());
    assert_eq!((m.patterns, m.matched, m.passes), (0, 0, 0));
    assert_eq!(m.host_rate, 0.0);
    assert_eq!((m.hw_seconds, m.hw_energy, m.hw_match_rate), (0.0, 0.0, 0.0));
    assert_eq!(m.lane_stats.len(), coord.lanes());
    assert!(m.lane_stats.iter().all(|s| s.items == 0 && s.passes == 0));
    // The coordinator still works afterwards.
    let (r2, _) = coord.run(&[vec![0u8; 16]]).unwrap();
    assert_eq!(r2.len(), 1);
}

#[test]
fn recoverable_lane_errors_are_typed_and_downcastable() {
    // Every supervision outcome surfaces a typed error (not a bare
    // string), so callers can distinguish "retry the run" from real
    // corruption. A panicked lane no longer poisons the coordinator:
    // the supervisor respawns it, and only budget exhaustion or a
    // wedge reaches the caller — as these variants.
    for e in [
        CoordinatorError::FaultDetected { pattern_id: 7, attempts: 16 },
        CoordinatorError::LaneQuarantined { lane: 1, restarts: 3 },
        CoordinatorError::LanesStalled { waited_ms: 250, missing: 4 },
    ] {
        let err = anyhow::Error::new(e);
        assert_eq!(err.downcast_ref::<CoordinatorError>(), Some(&e));
        assert!(!err.to_string().is_empty());
    }
    assert!(anyhow::Error::new(CoordinatorError::LaneQuarantined { lane: 1, restarts: 3 })
        .to_string()
        .contains("quarantined"));
    assert!(anyhow::Error::new(CoordinatorError::LanesStalled { waited_ms: 250, missing: 4 })
        .to_string()
        .contains("stalled"));
}

/// Satellite acceptance: an engine that panics mid-batch neither hangs
/// `Coordinator::run` nor corrupts the merge. The supervisor respawns
/// the lane in place, the interrupted item re-executes, and the merged
/// answers are bit-identical to a clean run — after which the
/// coordinator keeps serving with no residual restarts.
#[test]
fn panicking_engine_mid_batch_recovers_bit_identically() {
    let w = DnaWorkload::generate(2048, 24, 16, 0.0, 13);
    let fragments = w.fragments(64, 16);
    let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    cfg.engine = EngineSpec::Cpu;
    cfg.oracular = None;
    cfg.lanes = 2;
    let clean = Coordinator::new(cfg.clone(), fragments.clone()).unwrap();
    let (want, _) = clean.run(&w.patterns).unwrap();

    let mut faulty = cfg;
    faulty.fault = Some(FaultPlan::panic_on_item(7));
    let coord = Coordinator::new(faulty, fragments).unwrap();
    let (got, m) = coord.run(&w.patterns).unwrap();
    assert_eq!(m.lane_restarts, 1, "exactly one supervised respawn");
    assert_eq!(got.len(), want.len());
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.pattern_id, b.pattern_id);
        assert_eq!(a.best, b.best, "pattern {}", a.pattern_id);
        assert_eq!(a.hits, b.hits, "pattern {}", a.pattern_id);
    }
    // The panic budget is spent: the next run is restart-free and
    // still bit-identical.
    let (again, m2) = coord.run(&w.patterns).unwrap();
    assert_eq!(m2.lane_restarts, 0, "respawned lane must keep serving");
    for (a, b) in again.iter().zip(&want) {
        assert_eq!(a.best, b.best);
        assert_eq!(a.hits, b.hits);
    }
}

#[test]
fn pattern_codes_out_of_alphabet_do_not_crash_bitsim() {
    // 2-bit codes are masked by construction; Encoded::from_bits
    // asserts even lengths. Feed the coordinator a pattern with a
    // (masked-out) high code — must either work or error, not panic.
    let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    cfg.engine = EngineSpec::Bitsim;
    let coord = Coordinator::new(cfg, vec![vec![1u8; 64]; 2]).unwrap();
    let _ = coord.run(&[vec![3u8; 16]]).unwrap();
}

#[test]
fn oversized_fragment_buffer_rejected_by_runtime() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(&dir).unwrap();
    let v = rt.variant("dna_small").unwrap().clone();
    let too_big = vec![0i32; v.rows * v.frag_chars + 1];
    assert!(rt.execute("dna_small", &too_big, &vec![0i32; v.pat_chars]).is_err());
}

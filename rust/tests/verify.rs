//! Integration tests for the static program verifier: the full
//! geometry × alphabet × mode × readout matrix the `verify-programs`
//! CLI sweeps, the mutation self-test harness, and the column-role
//! queries the verifier's dataflow rules are built on.

use cram_pm::alphabet::Alphabet;
use cram_pm::array::{ColumnRole, RowLayout};
use cram_pm::isa::verify::corrupt;
use cram_pm::isa::{
    mutation_self_test, verify, Corruption, PresetMode, ProgramCache, Rule, VerifyReport, Violation,
};

/// Every compiled program of every (alphabet, mode, readout) cell at a
/// deliberately odd geometry verifies, and the cache's aggregate report
/// is exactly the fold of the per-program reports.
#[test]
fn full_matrix_verifies_with_consistent_aggregates() {
    let (frag_chars, pat_chars) = (33, 8);
    for alphabet in Alphabet::ALL {
        for mode in [PresetMode::Standard, PresetMode::Gang] {
            for readout in [false, true] {
                let cache =
                    ProgramCache::for_alphabet(alphabet, frag_chars, pat_chars, mode, readout)
                        .unwrap_or_else(|e| {
                            panic!("{} {mode:?} readout={readout}: {e}", alphabet.tag())
                        });
                assert_eq!(cache.len(), cache.layout().n_alignments());
                let mut folded = VerifyReport::default();
                for loc in 0..cache.len() as u32 {
                    let rep = verify(cache.program(loc), cache.layout()).unwrap_or_else(|e| {
                        panic!("{} {mode:?} readout={readout} loc={loc}: {e}", alphabet.tag())
                    });
                    folded.absorb(&rep);
                }
                assert_eq!(
                    folded,
                    cache.verify_report(),
                    "{} {mode:?} readout={readout}: aggregate drifted",
                    alphabet.tag()
                );
                // The census never loses instructions: everything is a
                // gate, a preset, or a read.
                let rep = cache.verify_report();
                assert_eq!(rep.instructions, rep.gates + rep.presets + rep.reads);
                assert_eq!(rep.reads, if readout { cache.len() } else { 0 });
            }
        }
    }
}

/// The issue-mandated corruption classes all exist, and every class is
/// rejected with its intended violation in both preset modes.
#[test]
fn all_corruption_classes_are_rejected_in_both_modes() {
    let mandated = [
        Corruption::DroppedPreset,
        Corruption::SwappedStage,
        Corruption::OutOfRangeColumn,
        Corruption::BadArity,
        Corruption::DanglingRead,
        Corruption::DeadStore,
        Corruption::ReorderedPreset,
        Corruption::WrongPolarityFold,
        Corruption::TrimmedLiveCone,
    ];
    for class in mandated {
        assert!(Corruption::ALL.contains(&class), "{} missing from ALL", class.name());
    }
    for mode in [PresetMode::Standard, PresetMode::Gang] {
        let cache = ProgramCache::for_geometry(24, 6, mode, true).unwrap();
        let rejections = mutation_self_test(&cache)
            .unwrap_or_else(|e| panic!("mutation self-test failed under {mode:?}: {e}"));
        assert_eq!(rejections.len(), Corruption::ALL.len());
        for (class, rejection) in &rejections {
            assert!(
                class.expects(rejection),
                "{} rejected with the wrong error under {mode:?}: {rejection}",
                class.name()
            );
        }
    }
}

/// A rejected corruption pinpoints the offending instruction: the error
/// carries a real index, the rule of its violation, and picks up the
/// alignment `loc` when attached.
#[test]
fn rejections_carry_index_rule_and_loc() {
    let cache = ProgramCache::for_geometry(24, 6, PresetMode::Gang, true).unwrap();
    let prog = cache.program(0);
    let layout = cache.layout();

    let mutated = corrupt(prog, layout, Corruption::DanglingRead).unwrap();
    let err = verify(&mutated, layout).unwrap_err();
    assert_eq!(err.index, 0, "the inserted read is the first instruction");
    assert_eq!(err.rule(), Rule::ReadoutCoverage);
    assert_eq!(err.loc, None);
    let err = err.with_loc(5);
    assert_eq!(err.loc, Some(5));
    let msg = err.to_string();
    assert!(msg.contains("instr #0") && msg.contains("alignment 5"), "{msg}");
    assert!(msg.contains("R5:readout-coverage"), "{msg}");

    let mutated = corrupt(prog, layout, Corruption::OutOfRangeColumn).unwrap();
    let err = verify(&mutated, layout).unwrap_err();
    assert_eq!(err.rule(), Rule::Geometry);
    let width = layout.total_cols() as u32;
    assert!(
        matches!(err.violation, Violation::ColumnOutOfRange { col, row_width }
            if col >= width && row_width == width),
        "{err}"
    );
}

/// Whole-cache builds reject a corrupted program and report the loc of
/// the program that failed — the always-on contract `ProgramCache::
/// build` gives every engine.
#[test]
fn cache_build_attaches_the_failing_loc() {
    // A healthy cache first, to steal a known-good layout from.
    let healthy = ProgramCache::for_geometry(24, 6, PresetMode::Gang, true).unwrap();
    let layout = *healthy.layout();
    // Every program of a fresh build at that layout verifies with the
    // loc attached on failure; simulate a failure by verifying a
    // corrupted copy the way build() does.
    let bad = corrupt(healthy.program(3), &layout, Corruption::DeadStore).unwrap();
    let err = verify(&bad, &layout).unwrap_err().with_loc(3);
    assert_eq!(err.loc, Some(3));
    assert_eq!(err.rule(), Rule::Liveness);
}

/// The column-role partition the dataflow rules rest on: every column
/// of a layout has exactly one role, roles appear in compartment order,
/// and out-of-range columns have none.
#[test]
fn column_roles_partition_the_row() {
    let layouts = [
        RowLayout::new(24, 6, 16),
        RowLayout::for_alphabet(Alphabet::Protein5, 16, 4, 24),
        RowLayout::for_alphabet(Alphabet::Ascii8, 12, 3, 8),
    ];
    for layout in layouts {
        let width = layout.total_cols() as u32;
        let mut last_role = ColumnRole::Fragment;
        for col in 0..width {
            let role = layout
                .column_role(col)
                .unwrap_or_else(|| panic!("column {col} of {width} has no role"));
            // Compartment order: Fragment ≤ Pattern ≤ Score ≤
            // MatchBits ≤ Scratch as the column index grows.
            assert!(
                role >= last_role,
                "role order broke at column {col}: {role:?} after {last_role:?}"
            );
            last_role = role;
            assert_eq!(layout.is_data_col(col), matches!(role, ColumnRole::Fragment | ColumnRole::Pattern));
            assert_eq!(layout.is_score_col(col), matches!(role, ColumnRole::Score));
        }
        assert_eq!(layout.column_role(width), None);
        assert_eq!(layout.column_role(u32::MAX), None);
        assert_eq!(
            layout.score_range(),
            layout.score_col()..layout.scratch_col(),
            "score_range must span exactly the score compartment"
        );
    }
}

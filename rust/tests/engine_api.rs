//! The engine-API keystone: every registered backend × every
//! configuration axis either answers bit-identically to the CPU oracle
//! or refuses — typed, at `Coordinator::new`, naming the engine and
//! the missing capability. Never a mid-run failure, never a silently
//! wrong answer. Plus the heterogeneous-lane guarantee: a mixed lane
//! set merges bit-identically to any homogeneous one at every split.

use cram_pm::alphabet::Alphabet;
use cram_pm::coordinator::{
    Coordinator, CoordinatorConfig, CoordinatorError, EngineSpec, Need, Requirements, WorkResult,
};
use cram_pm::engine::registered;
use cram_pm::fault::FaultPlan;
use cram_pm::semantics::MatchSemantics;
use cram_pm::util::Rng;

const FRAG_CHARS: usize = 24;
const PAT_CHARS: usize = 6;

/// A small deterministic workload: 12 fragments, 6 patterns, half of
/// them planted (full-score hits exist) and half random.
fn workload(alphabet: Alphabet, seed: u64) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
    let mut rng = Rng::new(seed);
    let fragments: Vec<Vec<u8>> =
        (0..12).map(|_| alphabet.random_codes(&mut rng, FRAG_CHARS)).collect();
    let patterns: Vec<Vec<u8>> = (0..6)
        .map(|i| {
            if i % 2 == 0 {
                fragments[i][3..3 + PAT_CHARS].to_vec()
            } else {
                alphabet.random_codes(&mut rng, PAT_CHARS)
            }
        })
        .collect();
    (fragments, patterns)
}

fn cfg_for(
    spec: &EngineSpec,
    alphabet: Alphabet,
    semantics: MatchSemantics,
    fault: Option<FaultPlan>,
) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::for_alphabet(alphabet, spec.clone(), FRAG_CHARS, PAT_CHARS);
    cfg.semantics = semantics;
    cfg.fault = fault;
    cfg.oracular = None;
    cfg.lanes = 2;
    cfg
}

/// The spec a registry name sweeps as. The XLA spec points at the
/// crate's artifact directory so the matrix is cwd-independent.
fn spec_for(name: &str) -> EngineSpec {
    match name {
        "xla" => EngineSpec::xla(
            "dna_small",
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        ),
        other => EngineSpec::parse(other).expect("every registry name parses"),
    }
}

fn assert_bit_identical(got: &[WorkResult], want: &[WorkResult], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: result count");
    for (a, b) in got.iter().zip(want) {
        assert_eq!(a.pattern_id, b.pattern_id, "{what}");
        assert_eq!(
            a.best.map(|x| (x.score, x.row, x.loc)),
            b.best.map(|x| (x.score, x.row, x.loc)),
            "{what}: best of pattern {}",
            a.pattern_id
        );
        assert_eq!(a.hits, b.hits, "{what}: hits of pattern {}", a.pattern_id);
    }
}

/// Satellite keystone: sweep every registered engine against every
/// alphabet × semantics × fault axis. Each cell must land in exactly
/// one of three honest outcomes:
///
/// 1. negotiation predicts a refusal → `Coordinator::new` fails with
///    `UnsupportedCapability` naming that engine and that need;
/// 2. construction fails for environmental reasons (XLA artifacts not
///    built, no wgpu adapter) → allowed only for those backends, and
///    never disguised as a capability refusal;
/// 3. construction succeeds → the run completes and is bit-identical
///    to the CPU oracle (fault off) or replays deterministically
///    (fault on) — a refusal can never first surface mid-run.
#[test]
fn capability_matrix_is_oracle_identical_or_typed_refusal() {
    let semantics_axis = [
        MatchSemantics::BestOf,
        MatchSemantics::Threshold { min_score: 4 },
        MatchSemantics::TopK { k: 3 },
    ];
    for factory in registered() {
        let spec = spec_for(factory.name);
        for (ai, alphabet) in Alphabet::ALL.into_iter().enumerate() {
            let (fragments, patterns) = workload(alphabet, 0xE2A9 + ai as u64);
            for semantics in semantics_axis {
                for fault in [None, Some(FaultPlan::rates(0.0, 0.0, 0.2, 11))] {
                    let cell = format!(
                        "{} × {alphabet} × {semantics} × fault={}",
                        factory.name,
                        fault.is_some()
                    );
                    let requirements = Requirements {
                        alphabet,
                        semantics,
                        device_faults: fault.as_ref().map_or(false, FaultPlan::rates_enabled),
                        forced_simd: None,
                    };
                    let predicted = factory.capabilities.unmet(&requirements);
                    let cfg = cfg_for(&spec, alphabet, semantics, fault.clone());
                    match (predicted, Coordinator::new(cfg, fragments.clone())) {
                        (Some(needs), Ok(_)) => {
                            panic!("{cell}: construction must refuse (needs {needs})")
                        }
                        (Some(needs), Err(err)) => match err.downcast_ref::<CoordinatorError>() {
                            Some(&CoordinatorError::UnsupportedCapability {
                                engine,
                                needs: got,
                                ..
                            }) => {
                                assert_eq!(engine, factory.name, "{cell}: refusal names engine");
                                assert_eq!(got, needs, "{cell}: refusal names the unmet need");
                            }
                            _ => panic!("{cell}: refusal must be UnsupportedCapability: {err:#}"),
                        },
                        (None, Err(err)) => {
                            // Environmental, not capability: only the
                            // backends with outside dependencies may
                            // fail a negotiated cell, and never with a
                            // capability refusal.
                            assert!(
                                matches!(factory.name, "xla" | "gpu"),
                                "{cell}: negotiated cell failed construction: {err:#}"
                            );
                            assert!(
                                !matches!(
                                    err.downcast_ref::<CoordinatorError>(),
                                    Some(CoordinatorError::UnsupportedCapability { .. })
                                ),
                                "{cell}: environmental failure disguised as a refusal: {err:#}"
                            );
                            eprintln!("skipping {cell}: {err:#}");
                        }
                        (None, Ok(coord)) => {
                            assert_eq!(coord.engine_label(), factory.name, "{cell}");
                            let (res, metrics) = coord.run(&patterns).unwrap_or_else(|err| {
                                panic!("{cell}: negotiated cell failed mid-run: {err:#}")
                            });
                            assert_eq!(metrics.engine, factory.name, "{cell}");
                            if fault.is_none() {
                                let oracle = Coordinator::new(
                                    cfg_for(&EngineSpec::Cpu, alphabet, semantics, None),
                                    fragments.clone(),
                                )
                                .unwrap();
                                let (want, _) = oracle.run(&patterns).unwrap();
                                assert_bit_identical(&res, &want, &cell);
                            } else {
                                // Faulted scores are engine-model
                                // specific; the contract is determinism:
                                // the same coordinator replays the same
                                // corrupted answers bit-identically.
                                let (again, _) = coord.run(&patterns).unwrap();
                                assert_bit_identical(&again, &res, &format!("{cell} replay"));
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Tentpole acceptance: heterogeneous lane sets answer bit-identically
/// to a single-engine run at every lane split, and the metrics label
/// reports the distinct lane engines in lane order.
#[test]
fn heterogeneous_lanes_merge_bit_identically_at_every_split() {
    let (fragments, patterns) = workload(Alphabet::Dna2, 77);
    let run_with = |lane_engines: Option<Vec<EngineSpec>>, lanes: usize| {
        let mut cfg = cfg_for(
            &EngineSpec::Cpu,
            Alphabet::Dna2,
            MatchSemantics::TopK { k: 3 },
            None,
        );
        cfg.lanes = lanes;
        cfg.lane_engines = lane_engines;
        let coord = Coordinator::new(cfg, fragments.clone()).unwrap();
        let (res, metrics) = coord.run(&patterns).unwrap();
        (res, metrics.engine)
    };
    let (want, label) = run_with(None, 1);
    assert_eq!(label, "cpu");
    for lanes in [1usize, 2, 3, 4] {
        let (got, label) = run_with(Some(vec![EngineSpec::Cpu, EngineSpec::Bitsim]), lanes);
        // Lane specs cycle; distinct labels join in lane order.
        assert_eq!(label, if lanes == 1 { "cpu" } else { "cpu+bitsim" }, "lanes={lanes}");
        assert_bit_identical(&got, &want, &format!("cpu+bitsim lanes={lanes}"));
    }
    let (bitsim_only, label) = run_with(Some(vec![EngineSpec::Bitsim]), 2);
    assert_eq!(label, "bitsim");
    assert_bit_identical(&bitsim_only, &want, "homogeneous bitsim lanes=2");
}

/// Negotiation covers every lane spec, not just `cfg.engine`: one
/// incapable engine anywhere in the mix refuses the whole lane set —
/// before any backend construction runs (the XLA spec here points at a
/// nonexistent artifact directory that is never touched).
#[test]
fn mixed_lane_negotiation_checks_every_spec() {
    let (fragments, _) = workload(Alphabet::Dna2, 5);
    let mut cfg = cfg_for(
        &EngineSpec::Cpu,
        Alphabet::Dna2,
        MatchSemantics::TopK { k: 2 },
        None,
    );
    cfg.lanes = 2;
    cfg.lane_engines =
        Some(vec![EngineSpec::Cpu, EngineSpec::xla("dna_small", "/nonexistent/artifacts")]);
    let err = Coordinator::new(cfg, fragments).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<CoordinatorError>(),
            Some(&CoordinatorError::UnsupportedCapability {
                engine: "xla",
                needs: Need::Enumeration(MatchSemantics::TopK { k: 2 }),
                ..
            })
        ),
        "unexpected: {err:#}"
    );
}

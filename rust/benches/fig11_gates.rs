//! Bench: regenerate Fig. 11 (gate-level throughput vs Ambit /
//! Pinatubo) and measure the *software* bulk-bitwise rate of the
//! columnar bit simulator for context.
//!
//! `cargo bench --bench fig11_gates`

use cram_pm::array::CramArray;
use cram_pm::experiments::fig11_gates;
use cram_pm::gates::GateKind;
use cram_pm::isa::{MicroInstr, Program, Stage};
use cram_pm::util::bench::{bench, section};

fn main() {
    section("Fig. 11 — data regeneration");
    fig11_gates::run();

    section("software columnar simulator: bulk bitwise rate");
    // 16K rows × one gate step = 16K bit-ops per execute.
    let rows = 16 * 1024;
    let mut arr = CramArray::new(rows, 8);
    for c in 0..4 {
        for r in (0..rows).step_by(c + 2) {
            arr.set(r, c, true);
        }
    }
    for (name, kind, ins) in [
        ("NOR2", GateKind::Nor2, vec![0u32, 1]),
        ("MAJ3", GateKind::Maj3, vec![0, 1, 2]),
        ("MAJ5", GateKind::Maj5, vec![0, 1, 2, 3, 4]),
    ] {
        let mut prog = Program::new();
        prog.push(Stage::Match, MicroInstr::gate(kind, 6, &ins));
        let r = bench(&format!("bitsim {name} ({rows} rows)"), 1.0, || {
            arr.execute(&prog).unwrap()
        });
        println!("{r}");
        println!("  → {:.2} Gbit-ops/s software", rows as f64 / r.median / 1e9);
    }
}

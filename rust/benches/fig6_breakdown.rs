//! Bench: regenerate Fig. 6 (stage breakdown) and time the per-stage
//! costing engine.
//!
//! `cargo bench --bench fig6_breakdown`

use cram_pm::experiments::fig6_breakdown;
use cram_pm::isa::{CodeGen, PresetMode};
use cram_pm::sim::{Simulator, SystemConfig};
use cram_pm::tech::Technology;
use cram_pm::util::bench::{bench, section};

fn main() {
    section("Fig. 6 — data regeneration");
    fig6_breakdown::run();

    section("Fig. 6 — costing throughput");
    let cfg = SystemConfig::paper_dna(Technology::NearTerm, PresetMode::Standard);
    let layout = cfg.layout();
    let sim = Simulator::new(cfg.tech, cfg.geometry());
    let mut cg = CodeGen::new(layout, cfg.preset_mode);
    let prog = cg.alignment_program(0, true);
    println!("program: {} micro-instructions per alignment", prog.len());
    let r = bench("cost_program (1 alignment, 100-char pattern)", 2.0, || sim.cost_program(&prog));
    println!("{r}");
    println!(
        "  → {:.1} M micro-instructions costed per second",
        prog.len() as f64 / r.median / 1e6
    );
}

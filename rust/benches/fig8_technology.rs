//! Bench: regenerate Fig. 8 (MTJ technology sensitivity).
//!
//! `cargo bench --bench fig8_technology`

use cram_pm::experiments::fig8_technology;
use cram_pm::util::bench::{bench, section};

fn main() {
    section("Fig. 8 — data regeneration");
    fig8_technology::run();

    section("Fig. 8 — sweep cost");
    let r = bench("near+long corner evaluation", 2.0, || fig8_technology::fig8(170.0));
    println!("{r}");
}

//! Bench: the L3 hot paths — codegen, the columnar bit simulator, the
//! oracular index, the XLA artifact execution, and the full pipeline.
//! This is the §Perf driver (EXPERIMENTS.md).
//!
//! `cargo bench --bench hotpath`

use cram_pm::array::{CramArray, RowLayout};
use cram_pm::bench_apps::dna::DnaWorkload;
use cram_pm::coordinator::{Coordinator, CoordinatorConfig, EngineKind};
use cram_pm::dna::Encoded;
use cram_pm::isa::{CodeGen, PresetMode};
use cram_pm::scheduler::{OracularScheduler, RowAddr};
use cram_pm::util::bench::{bench, section};
use cram_pm::util::Rng;

fn main() {
    let mut rng = Rng::new(1234);

    section("codegen: macro → micro lowering");
    let probe = RowLayout::new(256, 100, usize::MAX / 2);
    let mut cg = CodeGen::new(probe, PresetMode::Gang);
    let scratch = {
        let _ = cg.alignment_program(0, true);
        cg.stats().scratch_high_water
    };
    let layout = RowLayout::new(256, 100, scratch);
    let mut cg = CodeGen::new(layout, PresetMode::Gang);
    let n_instr = cg.alignment_program(0, true).len();
    let r = bench("alignment_program (100-char pattern)", 2.0, || cg.alignment_program(7, true));
    println!("{r}");
    println!("  → {:.1} M micro-instructions generated/s", n_instr as f64 / r.median / 1e6);

    section("columnar bit simulator: full Algorithm 1 iteration");
    let rows = 1024;
    let mut arr = CramArray::new(rows, layout.total_cols());
    for row in 0..rows {
        let frag = Encoded::from_ascii(&rng.dna(256));
        arr.write_encoded(row, layout.frag_col() as usize, &frag);
    }
    arr.broadcast_encoded(layout.pat_col() as usize, &Encoded::from_ascii(&rng.dna(100)));
    let prog = cg.alignment_program(0, true);
    let r = bench(&format!("execute 1 alignment ({} micros, {rows} rows)", prog.len()), 2.0, || {
        arr.execute(&prog).unwrap()
    });
    println!("{r}");
    println!(
        "  → {:.2} M row-gate-ops/s",
        (prog.len() * rows) as f64 / r.median / 1e6
    );

    section("oracular index");
    let w = DnaWorkload::generate(1 << 20, 4096, 24, 0.01, 7);
    let frags = w.fragments(256, 24);
    let addrs: Vec<RowAddr> =
        (0..frags.len()).map(|i| RowAddr { array: 0, row: i as u32 }).collect();
    let r = bench("index build (1M-char reference)", 3.0, || {
        OracularScheduler::build(&frags, addrs.clone(), w.patterns.clone(), 12, 64)
    });
    println!("{r}");
    let idx = OracularScheduler::build(&frags, addrs, w.patterns.clone(), 12, 64);
    let pats = w.patterns.clone();
    let mut i = 0;
    let r = bench("candidate lookup", 1.0, || {
        i = (i + 1) % pats.len();
        idx.candidates(&pats[i])
    });
    println!("{r}");
    println!("  → {:.2} M lookups/s", 1.0 / r.median / 1e6);

    // Lane sweep (EXPERIMENTS.md §Lane sweep): the sharded execute
    // stage on the DNA workload, CPU oracle engine so it runs with no
    // artifacts. Naive broadcast makes the execute stage the bottleneck
    // — exactly what the lanes parallelize.
    section("coordinator lane sweep (DNA workload, CPU engine)");
    {
        let w = DnaWorkload::generate(1 << 16, 64, 16, 0.0, 11);
        let frags = w.fragments(64, 16);
        let n_pats = w.patterns.len();
        let mut base_rate = 0.0;
        for lanes in [1usize, 2, 4, 8] {
            let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
            cfg.engine = EngineKind::Cpu;
            cfg.oracular = None;
            cfg.lanes = lanes;
            let coord = Coordinator::new(cfg, frags.clone()).unwrap();
            let r = bench(&format!("{n_pats} patterns broadcast, lanes={lanes}"), 3.0, || {
                coord.run(&w.patterns).unwrap()
            });
            println!("{r}");
            let rate = n_pats as f64 / r.median;
            if lanes == 1 {
                base_rate = rate;
            }
            println!(
                "  → {:.0} patterns/s host throughput ({:.2}× vs lanes=1)",
                rate,
                rate / base_rate
            );
        }
    }

    if std::path::Path::new("artifacts/manifest.txt").exists() {
        section("XLA artifact execution (dna_small: 256×64, pat 16)");
        let rt = cram_pm::runtime::Runtime::load(std::path::Path::new("artifacts")).unwrap();
        let frag: Vec<i32> = (0..256 * 64).map(|_| rng.below(4) as i32).collect();
        let pat: Vec<i32> = (0..16).map(|_| rng.below(4) as i32).collect();
        let r = bench("execute dna_small", 2.0, || rt.execute("dna_small", &frag, &pat).unwrap());
        println!("{r}");
        println!(
            "  → {:.2} M row-alignments/s through PJRT",
            (256 * 49) as f64 / r.median / 1e6
        );

        section("coordinator pipeline end-to-end (XLA engine)");
        let w = DnaWorkload::generate(1 << 17, 512, 16, 0.0, 3);
        let frags = w.fragments(64, 16);
        let cfg = CoordinatorConfig::xla("dna_small", 64, 16);
        let coord = Coordinator::new(cfg, frags.clone()).unwrap();
        let r = bench("512 patterns through the pipeline", 5.0, || coord.run(&w.patterns).unwrap());
        println!("{r}");
        println!("  → {:.0} patterns/s host throughput", 512.0 / r.median);

        let mut cfg2 = CoordinatorConfig::xla("dna_small", 64, 16);
        cfg2.engine = EngineKind::Cpu;
        let coord2 = Coordinator::new(cfg2, frags).unwrap();
        let r = bench("same, CPU oracle engine", 5.0, || coord2.run(&w.patterns).unwrap());
        println!("{r}");
    } else {
        eprintln!("(artifacts missing — skipping XLA benches; run `make artifacts`)");
    }
}

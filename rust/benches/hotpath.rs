//! Bench: the L3 hot paths — codegen, the columnar bit simulator, the
//! gate-level engine's simulate-one-pass path (fresh-everything vs the
//! cached/pooled hot path), the packed CPU scorer, the oracular index,
//! the XLA artifact execution, and the full pipeline. This is the
//! §Perf / §Hotpath driver (EXPERIMENTS.md).
//!
//! ```text
//! cargo bench --bench hotpath                      # full scale
//! cargo bench --bench hotpath -- --smoke           # CI size
//! cargo bench --bench hotpath -- --json BENCH_hotpath.json
//! ```
//!
//! The `--json` report is the committed perf baseline
//! (`BENCH_hotpath.json`): the headline `bitsim.speedup` compares a
//! fresh-everything pass (re-lower the alignment programs per pass,
//! new array, allocating read-outs) against the cached-program +
//! pooled-buffer engine on the same work item, inside one binary on
//! one host. Note the fresh side still goes through the word-parallel
//! write/read-out code (the bit-at-a-time I/O no longer exists), so
//! the measured ratio isolates the cache + pooling amortization and
//! *understates* the full delta vs the true pre-PR path.

use cram_pm::array::{CramArray, RowLayout};
use cram_pm::bench_apps::dna::DnaWorkload;
use cram_pm::coordinator::{
    BitsimEngine, Coordinator, CoordinatorConfig, CpuEngine, Engine, EngineSpec,
    SimdKernel, WorkItem,
};
use cram_pm::dna::{packed_best_alignment, Encoded, Packed2};
use cram_pm::isa::{CodeGen, PresetMode, ProgramCache};
use cram_pm::scheduler::{OracularScheduler, RowAddr};
use cram_pm::util::bench::{bench, section};
use cram_pm::util::{Json, Rng};
use std::sync::Arc;

/// Default engine geometry (the coordinator's): 64-char fragments,
/// 16-char patterns, 256 rows per block.
const FRAG_CHARS: usize = 64;
const PAT_CHARS: usize = 16;
const ROWS_PER_BLOCK: usize = 256;

/// One block-sized work item at the default geometry.
fn default_item(rng: &mut Rng) -> WorkItem {
    let fragments: Vec<Arc<[u8]>> = (0..ROWS_PER_BLOCK)
        .map(|_| Arc::from(cram_pm::dna::encode(&rng.dna(FRAG_CHARS)).as_slice()))
        .collect();
    let pattern: Arc<[u8]> = Arc::from(&fragments[7][5..5 + PAT_CHARS]);
    WorkItem {
        pattern_id: 0,
        alphabet: cram_pm::alphabet::Alphabet::Dna2,
        semantics: cram_pm::semantics::MatchSemantics::BestOf,
        pattern,
        fragments,
        row_ids: (0..ROWS_PER_BLOCK as u32).collect(),
    }
}

/// The fresh-everything reference: re-lower every alignment program
/// (`CodeGen::new` per pass), allocate a fresh `CramArray`, and take
/// allocating `execute` outputs — the pre-PR *structure*, though its
/// I/O now shares the word-parallel fast paths (see module docs).
fn fresh_everything_pass(layout: RowLayout, mode: PresetMode, item: &WorkItem) -> u64 {
    let mut arr = CramArray::new(item.fragments.len(), layout.total_cols());
    for (r, frag) in item.fragments.iter().enumerate() {
        arr.write_encoded(r, layout.frag_col() as usize, &Encoded { codes: frag.to_vec() });
    }
    arr.broadcast_encoded(layout.pat_col() as usize, &Encoded { codes: item.pattern.to_vec() });
    let mut cg = CodeGen::new(layout, mode);
    let mut best = 0u64;
    for loc in 0..layout.n_alignments() as u32 {
        let prog = cg.alignment_program(loc, true);
        let out = arr.execute(&prog).unwrap();
        for &s in &out.scores[0] {
            best = best.max(s);
        }
    }
    best
}

/// The pre-PR CPU scoring path: a `Vec<usize>` score profile per
/// (fragment, loc) scan.
fn profile_scan_item(item: &WorkItem) -> usize {
    let mut best = 0usize;
    for frag in &item.fragments {
        for &s in &cram_pm::dna::score_profile(frag, &item.pattern) {
            best = best.max(s);
        }
    }
    best
}

/// The packed XOR+popcount scorer on the same item.
fn packed_scan_item(item: &WorkItem) -> usize {
    let pattern = Packed2::from_codes(&item.pattern);
    let mut best = 0usize;
    for frag in &item.fragments {
        if let Some((s, _)) = packed_best_alignment(&Packed2::from_codes(frag), &pattern) {
            best = best.max(s);
        }
    }
    best
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(std::path::PathBuf::from);
    // Budgets: smoke keeps CI fast, full chases stable medians.
    let budget = if smoke { 0.25 } else { 2.0 };

    let mut rng = Rng::new(1234);
    let mode = PresetMode::Gang;

    section("codegen: macro → micro lowering");
    let probe = RowLayout::new(256, 100, usize::MAX / 2);
    let mut cg = CodeGen::new(probe, mode);
    let scratch = {
        let _ = cg.alignment_program(0, true);
        cg.stats().scratch_high_water
    };
    let layout100 = RowLayout::new(256, 100, scratch);
    let mut cg = CodeGen::new(layout100, mode);
    let n_instr = cg.alignment_program(0, true).len();
    let r_codegen =
        bench("alignment_program (100-char pattern)", budget, || cg.alignment_program(7, true));
    println!("{r_codegen}");
    println!(
        "  → {:.1} M micro-instructions generated/s",
        n_instr as f64 / r_codegen.median / 1e6
    );
    let r_cache_build = bench("ProgramCache::for_geometry (64×16 default)", budget, || {
        ProgramCache::for_geometry(FRAG_CHARS, PAT_CHARS, mode, true).unwrap()
    });
    println!("{r_cache_build}");
    println!("  (amortized once per coordinator, shared by every lane)");

    section("columnar bit simulator: full Algorithm 1 iteration");
    let rows = 1024;
    let mut arr = CramArray::new(rows, layout100.total_cols());
    for row in 0..rows {
        let frag = Encoded::from_ascii(&rng.dna(256));
        arr.write_encoded(row, layout100.frag_col() as usize, &frag);
    }
    arr.broadcast_encoded(layout100.pat_col() as usize, &Encoded::from_ascii(&rng.dna(100)));
    let prog = cg.alignment_program(0, true);
    let r = bench(&format!("execute 1 alignment ({} micros, {rows} rows)", prog.len()), budget, || {
        arr.execute(&prog).unwrap()
    });
    println!("{r}");
    println!("  → {:.2} M row-gate-ops/s", (prog.len() * rows) as f64 / r.median / 1e6);

    // The headline: one engine pass (256 rows × 49 alignments at the
    // default geometry), pre-PR fresh-everything path vs the cached
    // program + pooled array/buffer hot path.
    section("bitsim engine: simulate one pass (default 64×16 geometry)");
    let item = default_item(&mut rng);
    let mut engine = BitsimEngine::new(FRAG_CHARS, PAT_CHARS, ROWS_PER_BLOCK, mode)
        .expect("default-geometry programs must pass the static verifier");
    let layout = *engine.layout();
    let n_alignments = layout.n_alignments();
    let r_fresh = bench("fresh-everything pass (pre-PR structure)", budget, || {
        fresh_everything_pass(layout, mode, &item)
    });
    println!("{r_fresh}");
    let r_cached = bench("cached programs + pooled buffers", budget, || engine.run(&item).unwrap());
    println!("{r_cached}");
    let bitsim_speedup = r_fresh.median / r_cached.median;
    println!(
        "  → {:.1} passes/s (was {:.1}) — {:.2}× ; {:.0} ns/alignment across {} rows",
        1.0 / r_cached.median,
        1.0 / r_fresh.median,
        bitsim_speedup,
        r_cached.median * 1e9 / n_alignments as f64,
        ROWS_PER_BLOCK
    );
    // Sanity: both paths must agree on the answer.
    let fresh_best = fresh_everything_pass(layout, mode, &item);
    let cached_best = engine.run(&item).unwrap().best.unwrap().score as u64;
    assert_eq!(fresh_best, cached_best, "fresh and cached paths diverged");

    section("cpu engine scorer: score_profile scan vs packed XOR+popcount");
    let r_profile =
        bench("score_profile scan (the pre-PR scorer)", budget, || profile_scan_item(&item));
    println!("{r_profile}");
    let r_packed = bench("packed 2-bit scorer", budget, || packed_scan_item(&item));
    println!("{r_packed}");
    let cpu_speedup = r_profile.median / r_packed.median;
    let cpu_alignments = (ROWS_PER_BLOCK * n_alignments) as f64;
    println!(
        "  → {:.2}× ; {:.1} ns/alignment packed vs {:.1} ns/alignment profile",
        cpu_speedup,
        r_packed.median * 1e9 / cpu_alignments,
        r_profile.median * 1e9 / cpu_alignments
    );
    assert_eq!(profile_scan_item(&item), packed_scan_item(&item), "cpu scorers diverged");

    // Per-kernel A/B: the same work item through `CpuEngine` under
    // every SIMD kernel compiled into this target. `scalar` runs the
    // per-row packed scan verbatim (the oracle the vector paths are
    // proven against); `avx2`/`neon` take the word-transposed block
    // path. Results must agree bit-for-bit before timing means
    // anything, so the oracle check runs first.
    section("simd dispatch: CpuEngine item scoring per kernel");
    let simd_kernels = SimdKernel::all_available();
    let oracle_best =
        CpuEngine::with_kernel(item.alphabet, SimdKernel::Scalar).run(&item).unwrap().best;
    for &kernel in &simd_kernels {
        let got = CpuEngine::with_kernel(item.alphabet, kernel).run(&item).unwrap().best;
        assert_eq!(got, oracle_best, "kernel {kernel} diverged from the scalar oracle");
    }
    let mut simd_medians: Vec<(SimdKernel, f64)> = Vec::new();
    for &kernel in &simd_kernels {
        let mut eng = CpuEngine::with_kernel(item.alphabet, kernel);
        let r = bench(&format!("score item, kernel={kernel}"), budget, || eng.run(&item).unwrap());
        println!("{r}");
        println!("  → {:.0} items/s", 1.0 / r.median);
        simd_medians.push((kernel, r.median));
    }
    // `all_available` lists the scalar oracle first.
    let simd_scalar_s = simd_medians[0].1;
    for &(kernel, median) in &simd_medians[1..] {
        println!("  → kernel {kernel}: {:.2}× vs scalar", simd_scalar_s / median);
    }

    section("oracular index");
    let (ref_chars, idx_pats) = if smoke { (1 << 16, 256) } else { (1 << 20, 4096) };
    let w = DnaWorkload::generate(ref_chars, idx_pats, 24, 0.01, 7);
    let frags = w.fragments(256, 24);
    let addrs: Vec<RowAddr> =
        (0..frags.len()).map(|i| RowAddr { array: 0, row: i as u32 }).collect();
    let r = bench(&format!("index build ({ref_chars}-char reference)"), budget.min(3.0), || {
        OracularScheduler::build(&frags, addrs.clone(), w.patterns.clone(), 12, 64)
    });
    println!("{r}");
    let idx = OracularScheduler::build(&frags, addrs, w.patterns.clone(), 12, 64);
    let pats = w.patterns.clone();
    let mut i = 0;
    let r = bench("candidate lookup", budget.min(1.0), || {
        i = (i + 1) % pats.len();
        idx.candidates(&pats[i])
    });
    println!("{r}");
    println!("  → {:.2} M lookups/s", 1.0 / r.median / 1e6);

    // Lane sweep (EXPERIMENTS.md §Lane sweep): the sharded execute
    // stage on the DNA workload, CPU oracle engine so it runs with no
    // artifacts. Naive broadcast makes the execute stage the bottleneck
    // — exactly what the lanes parallelize.
    section("coordinator lane sweep (DNA workload, CPU engine)");
    {
        let (sweep_ref, lanes_list): (usize, &[usize]) =
            if smoke { (1 << 13, &[1, 2]) } else { (1 << 16, &[1, 2, 4, 8]) };
        let w = DnaWorkload::generate(sweep_ref, 64, 16, 0.0, 11);
        let frags = w.fragments(64, 16);
        let n_pats = w.patterns.len();
        let mut base_rate = 0.0;
        for &lanes in lanes_list {
            let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
            cfg.engine = EngineSpec::Cpu;
            cfg.oracular = None;
            cfg.lanes = lanes;
            let coord = Coordinator::new(cfg, frags.clone()).unwrap();
            let r = bench(&format!("{n_pats} patterns broadcast, lanes={lanes}"), budget.min(3.0), || {
                coord.run(&w.patterns).unwrap()
            });
            println!("{r}");
            let rate = n_pats as f64 / r.median;
            if lanes == 1 {
                base_rate = rate;
            }
            println!(
                "  → {:.0} patterns/s host throughput ({:.2}× vs lanes=1)",
                rate,
                rate / base_rate
            );
        }
    }

    if std::path::Path::new("artifacts/manifest.txt").exists() {
        section("XLA artifact execution (dna_small: 256×64, pat 16)");
        let rt = cram_pm::runtime::Runtime::load(std::path::Path::new("artifacts")).unwrap();
        let frag: Vec<i32> = (0..256 * 64).map(|_| rng.below(4) as i32).collect();
        let pat: Vec<i32> = (0..16).map(|_| rng.below(4) as i32).collect();
        let r = bench("execute dna_small", budget, || rt.execute("dna_small", &frag, &pat).unwrap());
        println!("{r}");
        println!(
            "  → {:.2} M row-alignments/s through PJRT",
            (256 * 49) as f64 / r.median / 1e6
        );

        section("coordinator pipeline end-to-end (XLA engine)");
        let w = DnaWorkload::generate(1 << 17, 512, 16, 0.0, 3);
        let frags = w.fragments(64, 16);
        let cfg = CoordinatorConfig::xla("dna_small", 64, 16);
        let coord = Coordinator::new(cfg, frags.clone()).unwrap();
        let r = bench("512 patterns through the pipeline", 5.0, || coord.run(&w.patterns).unwrap());
        println!("{r}");
        println!("  → {:.0} patterns/s host throughput", 512.0 / r.median);

        let mut cfg2 = CoordinatorConfig::xla("dna_small", 64, 16);
        cfg2.engine = EngineSpec::Cpu;
        let coord2 = Coordinator::new(cfg2, frags).unwrap();
        let r = bench("same, CPU oracle engine", 5.0, || coord2.run(&w.patterns).unwrap());
        println!("{r}");
    } else {
        eprintln!("(artifacts missing — skipping XLA benches; run `make artifacts`)");
    }

    if let Some(path) = json_path {
        // Per-kernel scorer rows. Only kernels compiled into and
        // detected on *this* host appear, so the committed anchor must
        // list only kernels the bench-smoke runner is guaranteed to
        // have (scalar + avx2 on the x86 runner) — a missing baseline
        // key fails the gate by design.
        let mut simd_rows = vec![("kernel", Json::str(SimdKernel::active().tag()))];
        for &(kernel, median) in &simd_medians {
            let mut row = vec![("items_per_sec", Json::num(1.0 / median))];
            if kernel != SimdKernel::Scalar {
                row.push(("speedup", Json::num(simd_scalar_s / median)));
            }
            simd_rows.push((kernel.tag(), Json::obj(row)));
        }
        let doc = Json::obj(vec![
            ("experiment", Json::str("hotpath")),
            ("smoke", Json::Bool(smoke)),
            (
                "provenance",
                Json::str(
                    "measured: in-binary A/B, fresh-everything (pre-PR structure; shares the \
                     word-parallel I/O) vs cached/pooled — understates the full pre-PR delta",
                ),
            ),
            (
                "geometry",
                Json::obj(vec![
                    ("frag_chars", Json::int(FRAG_CHARS)),
                    ("pat_chars", Json::int(PAT_CHARS)),
                    ("rows_per_block", Json::int(ROWS_PER_BLOCK)),
                    ("alignments_per_pass", Json::int(n_alignments)),
                    ("preset_mode", Json::str("Gang")),
                ]),
            ),
            (
                "bitsim",
                Json::obj(vec![
                    ("fresh_pass_s", Json::num(r_fresh.median)),
                    ("cached_pass_s", Json::num(r_cached.median)),
                    ("fresh_passes_per_sec", Json::num(1.0 / r_fresh.median)),
                    ("passes_per_sec", Json::num(1.0 / r_cached.median)),
                    ("speedup", Json::num(bitsim_speedup)),
                    (
                        "ns_per_alignment",
                        Json::num(r_cached.median * 1e9 / n_alignments as f64),
                    ),
                ]),
            ),
            (
                "cpu_scorer",
                Json::obj(vec![
                    ("profile_item_s", Json::num(r_profile.median)),
                    ("packed_item_s", Json::num(r_packed.median)),
                    ("speedup", Json::num(cpu_speedup)),
                    (
                        "packed_ns_per_alignment",
                        Json::num(r_packed.median * 1e9 / cpu_alignments),
                    ),
                ]),
            ),
            ("simd_scorer", Json::obj(simd_rows)),
            (
                "codegen",
                Json::obj(vec![
                    ("alignment_program_s", Json::num(r_codegen.median)),
                    ("cache_build_s", Json::num(r_cache_build.median)),
                ]),
            ),
            // Static-verifier census of the default-geometry cache:
            // exact structural counts, gated by bench-gate so a codegen
            // change that alters the microcode shape is visible. The
            // pre-optimization report keeps the anchor pinned to what
            // codegen emits; the optimizer's deltas are gated below.
            (
                "verify",
                Json::obj(vec![
                    ("programs", Json::int(engine.cache().len())),
                    ("instructions", Json::int(engine.cache().unoptimized_report().instructions)),
                    ("gates", Json::int(engine.cache().unoptimized_report().gates)),
                    ("presets", Json::int(engine.cache().unoptimized_report().presets)),
                    ("full_adders", Json::int(engine.cache().stats().full_adders)),
                ]),
            ),
            // Optimizer census at the default geometry: exact counts
            // of what O1 removed from the executed programs (every
            // rewrite re-verified and proven output-equivalent), gated
            // so a pass regression — eliminating less, or nothing — is
            // as visible as a codegen shape change.
            (
                "optimizer",
                Json::obj(vec![
                    ("opt_level", Json::str(engine.cache().opt_level().name())),
                    (
                        "instructions_eliminated",
                        Json::int(engine.cache().opt_census().instructions_eliminated),
                    ),
                    (
                        "gates_eliminated",
                        Json::int(engine.cache().opt_census().gates_eliminated),
                    ),
                    (
                        "presets_eliminated",
                        Json::int(engine.cache().opt_census().presets_eliminated),
                    ),
                ]),
            ),
        ]);
        doc.write_file(&path).expect("writing hotpath JSON report");
        println!("\nwrote {}", path.display());
    }
}

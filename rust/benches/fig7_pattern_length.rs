//! Bench: regenerate Fig. 7 (pattern-length sensitivity).
//!
//! `cargo bench --bench fig7_pattern_length`

use cram_pm::experiments::fig7_pattern_length;
use cram_pm::tech::Technology;
use cram_pm::util::bench::{bench, section};

fn main() {
    section("Fig. 7 — data regeneration");
    fig7_pattern_length::run();

    section("Fig. 7 — sweep cost");
    let r = bench("pattern-length sweep {100,200,300}", 2.0, || {
        fig7_pattern_length::fig7(Technology::NearTerm, &[100, 200, 300], 170.0)
    });
    println!("{r}");
}

//! Bench: the serving layer — per-request dispatch vs micro-batching
//! with cross-request dedup, under concurrent closed-loop clients on a
//! Zipfian pattern mix (EXPERIMENTS.md §Serving).
//!
//! `cargo bench --bench serving`

use cram_pm::bench_apps::dna::DnaWorkload;
use cram_pm::coordinator::{Coordinator, CoordinatorConfig, EngineSpec};
use cram_pm::serve::load::closed_loop;
use cram_pm::serve::{Backpressure, MatchServer, ServeConfig};
use cram_pm::util::bench::section;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    section("serving layer: batch=1 vs batched+dedup (CPU engine, Zipf s=1.1, 4 clients)");
    let w = DnaWorkload::generate(1 << 14, 128, 16, 0.0, 99);
    let fragments = w.fragments(64, 16);
    let mut cfg = CoordinatorConfig::xla("dna_small", 64, 16);
    cfg.engine = EngineSpec::Cpu;
    cfg.lanes = 4;
    let coordinator = Arc::new(Coordinator::new(cfg, fragments).unwrap());

    // max_batch = clients × patterns/request: steady-state batches
    // close by size, not by the max_delay deadline.
    let mut base = 0.0;
    for (label, max_batch, dedup) in
        [("batch=1", 1usize, false), ("batched (32)", 32, false), ("batched+dedup (32)", 32, true)]
    {
        let server = MatchServer::start(
            Arc::clone(&coordinator),
            ServeConfig {
                max_batch,
                max_delay: Duration::from_micros(200),
                queue_depth: 256,
                backpressure: Backpressure::Block,
                dedup,
                max_hits: 4096,
                deadline: None,
            },
        )
        .unwrap();
        let report = closed_loop(&server, &w.patterns, 4, 48, 8, 1.1, 7).unwrap();
        let totals = server.shutdown();
        if base == 0.0 {
            base = report.pattern_rate;
        }
        println!(
            "  {label:<22} {:>10.0} patterns/s ({:.2}× vs batch=1)  p50 {:>7.2} ms  \
             p99 {:>7.2} ms  dedup×{:.2}",
            report.pattern_rate,
            report.pattern_rate / base,
            report.latency.p50 * 1e3,
            report.latency.p99 * 1e3,
            totals.dedup_factor()
        );
    }
    println!(
        "\n  batching amortizes the lane-mutex acquisition; dedup collapses Zipfian\n  \
         duplicates to one execution each — both rise with client concurrency."
    );
}

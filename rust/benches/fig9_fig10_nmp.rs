//! Bench: regenerate Figs. 9 & 10 (CRAM-PM vs NMP across the Table 4
//! benchmark suite).
//!
//! `cargo bench --bench fig9_fig10_nmp`

use cram_pm::experiments::fig9_10_nmp;
use cram_pm::util::bench::{bench, section};

fn main() {
    section("Figs. 9/10 — data regeneration");
    fig9_10_nmp::run();

    section("Figs. 9/10 — suite evaluation cost");
    let r = bench("all 5 benchmarks × 2 corners", 2.0, fig9_10_nmp::fig9_10);
    println!("{r}");
}

//! Bench: regenerate Fig. 5 (design-point characterization) and time
//! the step-accurate model that produces it.
//!
//! `cargo bench --bench fig5_dna`

use cram_pm::experiments::fig5_designs;
use cram_pm::isa::PresetMode;
use cram_pm::sim::{DnaPassModel, SystemConfig};
use cram_pm::tech::Technology;
use cram_pm::util::bench::{bench, section};

fn main() {
    section("Fig. 5 — data regeneration");
    fig5_designs::run();

    section("Fig. 5 — model cost");
    for mode in [PresetMode::Standard, PresetMode::Gang] {
        let r = bench(&format!("pass_cost paper_dna {mode:?}"), 1.0, || {
            DnaPassModel::new(SystemConfig::paper_dna(Technology::NearTerm, mode)).pass_cost()
        });
        println!("{r}");
    }
    let r = bench("fig5 full regeneration", 2.0, || {
        fig5_designs::fig5(Technology::NearTerm, 3_000_000, 170.0)
    });
    println!("{r}");
}

"""AOT export: lower the L2 model to HLO **text** artifacts for the
rust PJRT runtime.

HLO text — not serialized ``HloModuleProto`` — is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids that the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.

Artifacts land in ``--out-dir`` together with ``manifest.txt``:

    <name> <rows> <frag_chars> <pat_chars> <file>

one line per variant — a whitespace format the rust side parses without
a JSON dependency (the build image is offline).

Run once via ``make artifacts``; python never runs on the request path.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# Exported shape variants: (name, rows, frag_chars, pat_chars).
# Rows are multiples of the kernel's 128-row VMEM block.
VARIANTS = [
    # Quickstart / integration-test scale.
    ("dna_small", 256, 64, 16),
    # The paper's 100-char patterns against kilocharacter fragments
    # (fragment folded to 256 to keep the artifact compile-time sane;
    # the coordinator tiles longer fragments over row blocks).
    ("dna_100", 256, 256, 100),
    # Word-count: single-alignment word match (Table 4, 32-bit words).
    ("wordcount", 512, 16, 16),
    # String-match: 10-char needles over 60-char segments (Table 4).
    ("stringmatch", 512, 60, 10),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, rows, frag, pat in VARIANTS:
        lowered = model.lower_variant(rows, frag, pat)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {rows} {frag} {pat} {fname}")
        print(f"wrote {path} ({len(text)} chars) [{rows}x{frag} pat={pat}]")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines)} variants, jax {jax.__version__}")


if __name__ == "__main__":
    main()

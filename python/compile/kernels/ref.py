"""Pure-jnp oracle for the CRAM-PM match computation (L1 correctness
reference).

Semantics being modelled (paper Algorithm 1): for every row and every
alignment ``loc``, count the characters of the pattern that equal the
aligned characters of the row's reference fragment. Characters are
2-bit codes (A=0, C=1, G=2, T=3).

The oracle is deliberately written with gather + compare — no bit
tricks — so that the Pallas kernel's bit-level implementation (XOR per
bit, NOR to a match bit, adder-tree popcount) is checked against an
independent formulation.
"""

import jax.numpy as jnp


def n_alignments(frag_chars: int, pat_chars: int) -> int:
    """Alignments per Algorithm 1: until the tails meet."""
    assert frag_chars >= pat_chars >= 1
    return frag_chars - pat_chars + 1


def score_profile_ref(frag_codes, pat_codes):
    """Similarity scores for every row and alignment.

    Args:
      frag_codes: int array ``(rows, frag_chars)`` of 2-bit codes.
      pat_codes: int array ``(pat_chars,)`` of 2-bit codes.

    Returns:
      int32 array ``(rows, frag_chars - pat_chars + 1)``.
    """
    frag_chars = frag_codes.shape[-1]
    pat_chars = pat_codes.shape[-1]
    n = n_alignments(frag_chars, pat_chars)
    idx = jnp.arange(n)[:, None] + jnp.arange(pat_chars)[None, :]
    windows = frag_codes[:, idx]  # (rows, n, pat)
    return jnp.sum(windows == pat_codes[None, None, :], axis=-1).astype(jnp.int32)


def best_alignment_ref(frag_codes, pat_codes):
    """Per-row ``(best_loc, best_score)`` — ties break to the lowest
    ``loc``, matching the rust coordinator's convention."""
    scores = score_profile_ref(frag_codes, pat_codes)
    best_loc = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    best_score = jnp.max(scores, axis=-1).astype(jnp.int32)
    return best_loc, best_score

"""L1: the CRAM-PM match kernel as a Pallas kernel.

The kernel mirrors the array's bit-level dataflow (paper §3.2):

* 2-bit character codes are compared **bit-wise** — XOR on the low bit,
  XOR on the high bit, then a NOR that collapses the two XOR outputs to
  the per-character match bit (Fig. 4a);
* the similarity score is the **popcount of the match string** — the
  role the Fig. 4b adder reduction tree plays in the array;
* rows are the parallel axis: every row computes the same alignment at
  the same time, exactly the row-level SIMD of §2.4. The Pallas grid
  tiles rows into VMEM blocks the way banks tile the reference across
  arrays (hardware adaptation: DESIGN.md §6).

Pallas runs under ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so the kernel lowers to plain HLO ops —
the form the rust runtime loads. On a real TPU the same BlockSpec
structure expresses the HBM→VMEM schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM block: 2-bit codes arrive as int32, so a (128, frag)
# block keeps the working set at frag ≈ 1000 chars around
# 128·1000·4 B ≈ 512 KB — half a TPU core's VMEM, leaving room for the
# output tile and double buffering.
DEFAULT_BLOCK_ROWS = 128


def _match_kernel(frag_ref, pat_ref, out_ref, *, pat_chars: int, n_align: int):
    """One row-block: sweep all alignments, bit-level compare + popcount."""
    frag = frag_ref[...]  # (block_rows, frag_chars) int32 codes
    pat = pat_ref[...]  # (1, pat_chars) int32 codes

    def alignment(loc, _):
        # Aligned window of the fragment (dynamic in loc, static size).
        window = jax.lax.dynamic_slice_in_dim(frag, loc, pat_chars, axis=1)
        # Bit-level comparison, exactly as the array does it:
        # two XORs per character...
        x = jnp.bitwise_xor(window, pat)
        x_lo = jnp.bitwise_and(x, 1)
        x_hi = jnp.bitwise_and(jnp.right_shift(x, 1), 1)
        # ...then NOR to the match bit (1 iff both bit-XORs are 0).
        match_bit = jnp.where(jnp.bitwise_or(x_lo, x_hi) == 0, 1, 0)
        # Adder-tree popcount of the match string = row-wise sum.
        score = jnp.sum(match_bit, axis=1, dtype=jnp.int32)
        out_ref[:, pl.dslice(loc, 1)] = score[:, None]
        return 0

    jax.lax.fori_loop(0, n_align, alignment, 0)


def match_scores(frag_codes, pat_codes, *, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Similarity scores ``(rows, n_align)`` via the Pallas kernel.

    ``rows`` must be a multiple of ``block_rows`` (the AOT variants are
    exported that way; the rust runtime pads the last block).
    """
    rows, frag_chars = frag_codes.shape
    pat_chars = pat_codes.shape[-1]
    n_align = frag_chars - pat_chars + 1
    if rows % block_rows != 0:
        raise ValueError(f"rows {rows} not a multiple of block_rows {block_rows}")

    kernel = functools.partial(_match_kernel, pat_chars=pat_chars, n_align=n_align)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Row-block of the fragment matrix into VMEM.
            pl.BlockSpec((block_rows, frag_chars), lambda i: (i, 0)),
            # The pattern is broadcast to every block (§3.2: the same
            # pattern is distributed across all rows).
            pl.BlockSpec((1, pat_chars), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, n_align), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n_align), jnp.int32),
        interpret=True,
    )(frag_codes, pat_codes.reshape(1, -1))

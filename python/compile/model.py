"""L2: the array-level match model (build-time JAX, never imported at
runtime).

Wraps the L1 Pallas kernel into the computation one CRAM-PM array pass
performs: all rows score their fragment against the pattern at every
alignment, and the per-row best alignment (the quantity the host
extracts from the score read-outs, §3.2 "Data Output") is reduced on
the spot so the rust coordinator gets ``(scores, best_loc,
best_score)`` in one executable.
"""

import jax
import jax.numpy as jnp

from compile.kernels import match as kernels


def array_pass(frag_codes, pat_codes):
    """One array pass.

    Args:
      frag_codes: int32 ``(rows, frag_chars)`` 2-bit codes, one fragment
        per row (the folded reference, Fig. 3).
      pat_codes: int32 ``(pat_chars,)`` 2-bit codes (the pattern,
        broadcast to all rows).

    Returns:
      Tuple of ``scores (rows, n_align) int32``, ``best_loc (rows,)
      int32`` (ties to the lowest loc) and ``best_score (rows,) int32``.
    """
    scores = kernels.match_scores(frag_codes, pat_codes)
    best_loc = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    best_score = jnp.max(scores, axis=-1).astype(jnp.int32)
    return scores, best_loc, best_score


def lower_variant(rows: int, frag_chars: int, pat_chars: int):
    """AOT-lower ``array_pass`` for a concrete shape; returns the
    jax ``Lowered`` object."""
    frag = jax.ShapeDtypeStruct((rows, frag_chars), jnp.int32)
    pat = jax.ShapeDtypeStruct((pat_chars,), jnp.int32)
    return jax.jit(array_pass).lower(frag, pat)

"""L1 correctness: the Pallas match kernel against the pure-jnp oracle.

This is the CORE correctness signal of the python side: the bit-level
kernel (XOR/NOR/popcount, the array's dataflow) must agree with the
independent gather-and-compare oracle on every shape and input.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from compile.kernels import match, ref


def random_codes(rng, *shape):
    return jnp.asarray(rng.integers(0, 4, size=shape), dtype=jnp.int32)


@pytest.mark.parametrize(
    "rows,frag,pat",
    [
        (128, 16, 4),
        (128, 64, 16),
        (256, 64, 16),
        (256, 256, 100),
        (512, 16, 16),  # single alignment (word match)
        (512, 60, 10),
        (128, 100, 1),  # single-char pattern
        (128, 33, 32),  # two alignments, odd sizes
    ],
)
def test_kernel_matches_oracle(rows, frag, pat):
    rng = np.random.default_rng(rows * 1000 + frag * 10 + pat)
    frag_codes = random_codes(rng, rows, frag)
    pat_codes = random_codes(rng, pat)
    got = match.match_scores(frag_codes, pat_codes)
    want = ref.score_profile_ref(frag_codes, pat_codes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_exact_match_scores_full_length():
    rng = np.random.default_rng(7)
    frag_codes = random_codes(rng, 128, 64)
    # Plant the pattern at loc=20 of row 3.
    pat_codes = frag_codes[3, 20:36]
    scores = np.asarray(match.match_scores(frag_codes, pat_codes))
    assert scores[3, 20] == 16
    assert scores.shape == (128, 49)


def test_mismatch_scores_below_full():
    frag_codes = jnp.zeros((128, 32), dtype=jnp.int32)  # all 'A'
    pat_codes = jnp.full((8,), 3, dtype=jnp.int32)  # all 'T'
    scores = np.asarray(match.match_scores(frag_codes, pat_codes))
    assert (scores == 0).all()


def test_half_character_bit_overlap_not_counted():
    # C (01) vs G (10): both bits differ; A (00) vs C (01): one bit
    # differs. Either way the character must not count as a match —
    # the NOR stage demands BOTH bit-XORs be zero.
    frag_codes = jnp.asarray([[1, 0, 2, 3]] * 128, dtype=jnp.int32)
    pat_codes = jnp.asarray([2, 1, 1, 3], dtype=jnp.int32)
    scores = np.asarray(match.match_scores(frag_codes, pat_codes))
    assert scores[0, 0] == 1  # only the final T==T matches


@settings(max_examples=40, deadline=None)
@given(
    rows_blocks=st.integers(1, 3),
    pat=st.integers(1, 24),
    extra=st.integers(0, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_oracle_hypothesis(rows_blocks, pat, extra, seed):
    """Property sweep over shapes: kernel == oracle for any geometry."""
    rows = rows_blocks * match.DEFAULT_BLOCK_ROWS
    frag = pat + extra
    rng = np.random.default_rng(seed)
    frag_codes = random_codes(rng, rows, frag)
    pat_codes = random_codes(rng, pat)
    got = np.asarray(match.match_scores(frag_codes, pat_codes))
    want = np.asarray(ref.score_profile_ref(frag_codes, pat_codes))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_scores_bounded_by_pattern_length(seed):
    rng = np.random.default_rng(seed)
    frag_codes = random_codes(rng, 128, 48)
    pat_codes = random_codes(rng, 12)
    scores = np.asarray(match.match_scores(frag_codes, pat_codes))
    assert scores.min() >= 0 and scores.max() <= 12


def test_rows_must_be_block_multiple():
    rng = np.random.default_rng(3)
    with pytest.raises(ValueError, match="block_rows"):
        match.match_scores(random_codes(rng, 100, 32), random_codes(rng, 8))


def test_custom_block_rows():
    rng = np.random.default_rng(4)
    frag_codes = random_codes(rng, 64, 32)
    pat_codes = random_codes(rng, 8)
    got = np.asarray(match.match_scores(frag_codes, pat_codes, block_rows=32))
    want = np.asarray(ref.score_profile_ref(frag_codes, pat_codes))
    np.testing.assert_array_equal(got, want)

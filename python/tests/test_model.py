"""L2 correctness: the array-pass model (kernel + best-alignment
reduction) and the AOT export path."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def codes(rng, *shape):
    return jnp.asarray(rng.integers(0, 4, size=shape), dtype=jnp.int32)


def test_array_pass_shapes_and_dtypes():
    rng = np.random.default_rng(0)
    scores, best_loc, best_score = model.array_pass(codes(rng, 128, 64), codes(rng, 16))
    assert scores.shape == (128, 49) and scores.dtype == jnp.int32
    assert best_loc.shape == (128,) and best_loc.dtype == jnp.int32
    assert best_score.shape == (128,) and best_score.dtype == jnp.int32


def test_best_alignment_matches_oracle():
    rng = np.random.default_rng(1)
    frag, pat = codes(rng, 128, 48), codes(rng, 12)
    _, best_loc, best_score = model.array_pass(frag, pat)
    want_loc, want_score = ref.best_alignment_ref(frag, pat)
    np.testing.assert_array_equal(np.asarray(best_loc), np.asarray(want_loc))
    np.testing.assert_array_equal(np.asarray(best_score), np.asarray(want_score))


def test_best_ties_break_low():
    # A constant fragment ties every alignment; argmax must pick loc 0.
    frag = jnp.zeros((128, 32), dtype=jnp.int32)
    pat = jnp.zeros((8,), dtype=jnp.int32)
    _, best_loc, best_score = model.array_pass(frag, pat)
    assert (np.asarray(best_loc) == 0).all()
    assert (np.asarray(best_score) == 8).all()


def test_planted_pattern_recovered():
    rng = np.random.default_rng(2)
    frag = codes(rng, 256, 64)
    pat = frag[77, 30:46]
    _, best_loc, best_score = model.array_pass(frag, pat)
    assert int(best_score[77]) == 16
    assert int(best_loc[77]) == 30


@pytest.mark.parametrize("name,rows,frag,pat", aot.VARIANTS)
def test_variants_lower_to_hlo_text(name, rows, frag, pat):
    """Every exported variant must lower and contain an HLO module."""
    lowered = model.lower_variant(rows, frag, pat)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), f"{name}: not HLO text"
    # All three outputs present as a tuple root.
    assert "ROOT" in text


def test_hlo_text_is_deterministic():
    a = aot.to_hlo_text(model.lower_variant(128, 32, 8))
    b = aot.to_hlo_text(model.lower_variant(128, 32, 8))
    assert a == b

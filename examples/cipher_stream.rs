//! RC4 stream ciphering on CRAM-PM (Table 4's RC4 benchmark as an
//! application): encrypt a message in-array, decrypt it in software,
//! and check the round trip.
//!
//! ```bash
//! cargo run --release --example cipher_stream
//! ```

use cram_pm::array::CramArray;
use cram_pm::bench_apps::rc4::{Rc4, Rc4Bench};
use cram_pm::bench_apps::Benchmark;
use cram_pm::isa::PresetMode;
use cram_pm::tech::Technology;

fn main() -> cram_pm::Result<()> {
    let message = b"in-memory computing fuses logic and storage; the overhead of moving \
                    data to the processor disappears when the processor is the memory.";
    println!("plaintext ({} bytes): {:?}", message.len(), String::from_utf8_lossy(message));

    // Segment the message into 62-bit row segments (the score buffer
    // streams 62 bits per slot) and generate the keystream with the
    // host-side PRGA.
    const SEG_BITS: usize = 62;
    let bench = Rc4Bench { words: message.len() / 4, segment_bits: SEG_BITS, rows: 64 };
    let spec = bench.pass_spec(PresetMode::Gang);
    let mut keystream = Rc4::new(b"spintronics");

    // Pack message bits row by row.
    let bits: Vec<bool> = message
        .iter()
        .flat_map(|&b| (0..8).map(move |i| b >> i & 1 == 1))
        .collect();
    let n_rows = bits.len().div_ceil(SEG_BITS);
    assert!(n_rows <= bench.rows);
    let mut arr = CramArray::new(bench.rows, spec.layout.total_cols());
    let mut key_bits_all: Vec<bool> = Vec::new();
    for r in 0..n_rows {
        for i in 0..SEG_BITS {
            let bit = bits.get(r * SEG_BITS + i).copied().unwrap_or(false);
            arr.set(r, spec.layout.frag_col() as usize + i, bit);
        }
    }
    // Keystream into the pattern compartment (8 bytes → 62 bits/row).
    for r in 0..bench.rows {
        let mut k = 0u64;
        for b in 0..8 {
            k |= (keystream.next_byte() as u64) << (8 * b);
        }
        for i in 0..SEG_BITS {
            let bit = k >> i & 1 == 1;
            arr.set(r, spec.layout.pat_col() as usize + i, bit);
            if r < n_rows {
                key_bits_all.push(bit);
            }
        }
    }

    // Fire the in-array XOR pass (the whole array ciphers in lock-step).
    let out = arr.execute(&spec.program)?;
    println!("\nciphered {} rows × {SEG_BITS} bits in one row-parallel pass", n_rows);

    // Reassemble ciphertext bits from the streamed-out scores.
    let mut cipher_bits = Vec::with_capacity(bits.len());
    for r in 0..n_rows {
        let v = out.scores[0][r];
        for i in 0..SEG_BITS {
            cipher_bits.push(v >> i & 1 == 1);
        }
    }

    // Decrypt in software: XOR with the same keystream bits.
    let plain_bits: Vec<bool> =
        cipher_bits.iter().zip(&key_bits_all).map(|(&c, &k)| c ^ k).collect();
    let mut recovered = vec![0u8; message.len()];
    for (i, byte) in recovered.iter_mut().enumerate() {
        for b in 0..8 {
            if plain_bits[i * 8 + b] {
                *byte |= 1 << b;
            }
        }
    }
    assert_eq!(&recovered, message, "round-trip failed");
    println!("round-trip decrypt OK: {:?}", String::from_utf8_lossy(&recovered[..40]));

    // What would this cost on the substrate?
    for tech in Technology::ALL {
        let r = Rc4Bench::paper().cram(tech, PresetMode::Gang);
        println!(
            "paper-scale RC4 on {tech}: {:.3e} words/s at {:.1} W over {} arrays",
            r.match_rate, r.power, r.arrays
        );
    }
    Ok(())
}

//! Gate playground: the electrical side of CRAM-PM (paper §2).
//!
//! Walks the resistive-divider analysis for every gate: bias windows,
//! per-state currents, the XOR and full-adder compound sequences, the
//! §3.4 row-width experiment and the §5.5 variation margins.
//!
//! ```bash
//! cargo run --release --example gate_playground
//! ```

use cram_pm::gates::compound::{full_adder_via_sequence, xor_via_sequence};
use cram_pm::gates::{gate_current, solve_window, GateKind};
use cram_pm::tech::interconnect::{max_row_width, InterconnectModel};
use cram_pm::tech::{MtjParams, Technology, VariationAnalysis};

fn main() {
    for tech in Technology::ALL {
        let mtj = MtjParams::for_technology(tech);
        println!("═══ {tech} MTJ: R_P={:.2}kΩ R_AP={:.2}kΩ I_crit(eff)={:.1}µA ═══",
            mtj.r_p / 1e3, mtj.r_ap / 1e3, mtj.i_crit_eff() * 1e6);

        for kind in GateKind::ALL {
            let w = solve_window(&mtj, kind, 0.0);
            let v = w.midpoint();
            print!(
                "  {:<5} pre-set {}  V_gate {v:.3} V  currents(µA):",
                kind.name(),
                kind.preset() as u8
            );
            for ones in 0..=kind.n_inputs() {
                let i = gate_current(&mtj, v, kind.n_inputs(), ones, kind.preset(), 0.0);
                let mark = if i > mtj.i_crit_eff() { "*" } else { " " };
                print!(" {ones}→{:.0}{mark}", i * 1e6);
            }
            println!("   (* = switches)");
        }
        println!();
    }

    println!("── compound sequences ──");
    println!("  XOR via NOR/COPY/TH (Table 2):");
    for a in [false, true] {
        for b in [false, true] {
            println!("    {} ⊕ {} = {}", a as u8, b as u8, xor_via_sequence(a, b) as u8);
        }
    }
    println!("  full adder via MAJ3/INV/COPY/MAJ5 (Fig. 2):");
    for a in [false, true] {
        for b in [false, true] {
            for c in [false, true] {
                let (s, co) = full_adder_via_sequence(a, b, c);
                println!(
                    "    {}+{}+{} = sum {} carry {}",
                    a as u8, b as u8, c as u8, s as u8, co as u8
                );
            }
        }
    }

    println!("\n── §3.4 row width (near-term, 22 nm copper LL) ──");
    let mtj = MtjParams::near_term();
    let wire = InterconnectModel::at_22nm();
    let a = max_row_width(&mtj, &wire, GateKind::Nor2);
    println!(
        "  2-input NOR keeps switching up to {} cells away (R_line {:.0} Ω, RC {:.2} % of t_sw)",
        a.max_cells,
        a.r_line_at_max,
        a.latency_overhead * 100.0
    );

    println!("\n── §5.5 variation margins (near-term) ──");
    let va = VariationAnalysis::new(mtj, 5000, 1);
    for kind in GateKind::ALL {
        let r = va.check_gate(kind, 0.10);
        println!(
            "  {:<5} ±10% I_crit: worst-case {}  MC yield {:.1} %",
            kind.name(),
            if r.functional_worst_case { "OK   " } else { "FAILS" },
            r.mc_yield * 100.0
        );
    }
}

//! Quickstart: match a few patterns against a small reference on the
//! gate-level CRAM-PM array — no artifacts needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cram_pm::array::{CramArray, RowLayout};
use cram_pm::dna::{encode, Encoded};
use cram_pm::isa::{CodeGen, PresetMode};

fn main() -> cram_pm::Result<()> {
    // A toy "genome" folded into four fragments (rows).
    let fragments: [&[u8]; 4] = [
        b"ACGTACGTACGTACGTACGTACGTACGTACGT",
        b"TTTTGGGGCCCCAAAATTTTGGGGCCCCAAAA",
        b"GATTACAGATTACAGATTACAGATTACAGATT",
        b"CCCCCCCCGGGGGGGGAAAAAAAATTTTTTTT",
    ];
    let pattern = b"GATTACAG";

    // Size the row layout for 32-char fragments and 8-char patterns;
    // scratch demand comes from a probe lowering.
    let probe = RowLayout::new(32, 8, usize::MAX / 2);
    let mut cg = CodeGen::new(probe, PresetMode::Gang);
    let _ = cg.alignment_program(0, true);
    let layout = RowLayout::new(32, 8, cg.stats().scratch_high_water);
    println!(
        "row layout: fragment@{} pattern@{} score@{} scratch@{} ({} columns total)",
        layout.frag_col(),
        layout.pat_col(),
        layout.score_col(),
        layout.scratch_col(),
        layout.total_cols()
    );

    // Load the array: one fragment per row, pattern broadcast (§3.2).
    let mut arr = CramArray::new(fragments.len(), layout.total_cols());
    for (r, f) in fragments.iter().enumerate() {
        arr.write_encoded(r, layout.frag_col() as usize, &Encoded::from_ascii(f));
    }
    arr.broadcast_encoded(layout.pat_col() as usize, &Encoded::from_ascii(pattern));

    // Run Algorithm 1: for every alignment, the two-phase
    // match + similarity-score program, all rows in lock-step.
    let mut cg = CodeGen::new(layout, PresetMode::Gang);
    let mut best: Vec<(usize, u64)> = vec![(0, 0); fragments.len()];
    for loc in 0..layout.n_alignments() as u32 {
        let prog = cg.alignment_program(loc, true);
        let out = arr.execute(&prog)?;
        for (row, &score) in out.scores[0].iter().enumerate() {
            if score > best[row].1 {
                best[row] = (loc as usize, score);
            }
        }
    }

    println!("\npattern {:?} best alignments:", std::str::from_utf8(pattern).unwrap());
    for (row, (loc, score)) in best.iter().enumerate() {
        println!(
            "  row {row}: score {score}/8 at loc {loc}   fragment {}",
            std::str::from_utf8(fragments[row]).unwrap()
        );
    }

    // Sanity: row 2 holds GATTACAG... at loc 0 (and every 7 chars).
    assert_eq!(best[2].1, 8, "exact match must score 8/8");
    let oracle = cram_pm::dna::score_profile(&encode(fragments[2]), &encode(pattern));
    assert_eq!(oracle[best[2].0], 8);
    println!("\nquickstart OK — in-array result agrees with the software oracle");
    Ok(())
}

//! CRAM-PM vs near-memory processing, benchmark by benchmark — the
//! Fig. 9/10 comparison as an interactive report, plus the gate-level
//! Fig. 11 face-off against Ambit and Pinatubo.
//!
//! ```bash
//! cargo run --release --example nmp_faceoff
//! ```

use cram_pm::baselines::{AmbitModel, BulkOp, CramGateModel, NmpBaseline, PinatuboModel};
use cram_pm::bench_apps::all_benchmarks;
use cram_pm::isa::PresetMode;
use cram_pm::tech::Technology;

fn main() {
    let nmp = NmpBaseline::paper();
    let hyp = NmpBaseline::hypothetical();
    println!(
        "NMP baseline: {} ARM-A5-class cores @ {:.0} MHz, {:.1} GB/s links, {:.2} W",
        nmp.cores,
        nmp.clock_hz / 1e6,
        nmp.link_bw / 1e9,
        nmp.power()
    );
    println!("NMP-Hyp: {} cores, zero memory overhead, {:.2} W\n", hyp.cores, hyp.power());

    for tech in Technology::ALL {
        println!("═══ {tech} ═══");
        println!(
            "  {:<5} {:>13} {:>13} {:>11} {:>11} {:>12} {:>12}",
            "bench", "CRAM (it/s)", "NMP (it/s)", "rate ×NMP", "rate ×Hyp", "eff ×NMP", "eff ×Hyp"
        );
        for b in all_benchmarks() {
            let cram = b.cram(tech, PresetMode::Gang);
            let p = b.nmp_profile();
            println!(
                "  {:<5} {:>13.3e} {:>13.3e} {:>10.0}× {:>10.0}× {:>11.0}× {:>11.0}×",
                b.name(),
                cram.match_rate,
                nmp.match_rate(&p),
                cram.match_rate / nmp.match_rate(&p),
                cram.match_rate / hyp.match_rate(&p),
                cram.efficiency / nmp.efficiency(&p),
                cram.efficiency / hyp.efficiency(&p),
            );
        }
        println!();
    }

    println!("═══ gate-level (Fig. 11): 32 MB bulk bitwise ═══");
    let ambit = AmbitModel::default();
    let vec_bits = 32 * 1024 * 1024 * 8;
    for tech in Technology::ALL {
        let cram = CramGateModel::new(tech);
        print!("  [{tech}]");
        for op in BulkOp::FIG11 {
            print!(
                "  {} {:.0}×",
                op.name(),
                cram.throughput(op, vec_bits) / ambit.throughput(op)
            );
        }
        println!(
            "  | Pinatubo-OR {:.1}×",
            cram.throughput(BulkOp::Or, vec_bits) / PinatuboModel::default().or_throughput()
        );
    }
}

//! **End-to-end driver** (the EXPERIMENTS.md §E2E run): the full
//! three-layer system on a real small workload.
//!
//! * generates a 256 K-character synthetic genome and 2 000 real
//!   100→16-char reads (1 % base error rate),
//! * folds the genome into per-row fragments with boundary overlap,
//! * routes every read through the L3 coordinator pipeline
//!   (k-mer Oracular scheduling → batched execution on the **AOT XLA
//!   artifact** produced by the L1 Pallas kernel + L2 JAX model →
//!   best-alignment reduction),
//! * validates recall against the software oracle,
//! * reports host throughput plus the step-accurate CRAM-PM substrate
//!   projection (time, energy, match rate).
//!
//! ```bash
//! make artifacts && cargo run --release --example dna_pipeline
//! ```

use cram_pm::baselines::CpuMatcher;
use cram_pm::bench_apps::dna::DnaWorkload;
use cram_pm::coordinator::{Coordinator, CoordinatorConfig, EngineSpec};
use std::time::Instant;

fn main() -> cram_pm::Result<()> {
    const REF_CHARS: usize = 262_144;
    const N_PATTERNS: usize = 2_000;
    const PAT_CHARS: usize = 16;
    const FRAG_CHARS: usize = 64;
    const ERROR_RATE: f64 = 0.01;

    println!("── workload ────────────────────────────────────────");
    let t0 = Instant::now();
    let w = DnaWorkload::generate(REF_CHARS, N_PATTERNS, PAT_CHARS, ERROR_RATE, 2024);
    let fragments = w.fragments(FRAG_CHARS, PAT_CHARS);
    println!(
        "reference {REF_CHARS} chars → {} fragments × {FRAG_CHARS} chars (+{PAT_CHARS}-char overlap)",
        fragments.len()
    );
    println!("{N_PATTERNS} reads × {PAT_CHARS} chars, {ERROR_RATE} error rate  [{:.2?}]", t0.elapsed());

    // The full pipeline on the XLA engine (falls back to the bit-level
    // engine if artifacts are missing, so the example always runs).
    let have_artifacts = std::path::Path::new("artifacts/manifest.txt").exists();
    let mut cfg = CoordinatorConfig::xla("dna_small", FRAG_CHARS, PAT_CHARS);
    if !have_artifacts {
        eprintln!("artifacts/ missing — run `make artifacts`; using the bit-level engine instead");
        cfg.engine = EngineSpec::Bitsim;
    }
    let coord = Coordinator::new(cfg, fragments.clone())?;

    println!("\n── pipeline run ({}) ───────────────────────────────", if have_artifacts { "XLA engine" } else { "bitsim engine" });
    let (results, m) = coord.run(&w.patterns)?;

    // Recall validation against the software oracle, over the same
    // candidate sets (the coordinator's answer must equal the oracle's
    // answer for the rows it routed to).
    println!("\n── validation ──────────────────────────────────────");
    let oracle = CpuMatcher::new(fragments);
    let mut agree = 0usize;
    for (i, r) in results.iter().enumerate().take(200) {
        let got = r.best.map(|b| b.score);
        let want = oracle.best(&w.patterns[i]).map(|b| b.score);
        // Oracular candidates may exclude the global best row for
        // erroneous reads; the coordinator can only be <= the oracle.
        if let (Some(g), Some(wnt)) = (got, want) {
            assert!(g <= wnt, "pattern {i}: pipeline {g} beats oracle {wnt}?!");
            if g == wnt {
                agree += 1;
            }
        }
    }
    println!("best-score agreement with oracle on sampled 200 reads: {agree}/200");

    let high = results
        .iter()
        .filter(|r| r.best.map_or(false, |b| b.score >= PAT_CHARS - 2))
        .count();
    println!(
        "reads recovering ≥{}/{} of their bases: {high}/{} ({:.1} %)",
        PAT_CHARS - 2,
        PAT_CHARS,
        results.len(),
        100.0 * high as f64 / results.len() as f64
    );
    assert!(high as f64 > 0.95 * results.len() as f64, "recall regression");

    println!("\n── report ──────────────────────────────────────────");
    println!("engine                 {}", m.engine);
    println!("patterns               {}", m.patterns);
    println!("engine passes          {}", m.passes);
    println!("mean candidate rows    {:.1}", m.mean_candidates);
    println!("host wall              {:.3} s  ({:.0} patterns/s)", m.wall_seconds, m.host_rate);
    println!("substrate projection   {:.3e} s, {:.3e} J", m.hw_seconds, m.hw_energy);
    println!("substrate match rate   {:.3e} patterns/s", m.hw_match_rate);
    println!("\ndna_pipeline OK");
    Ok(())
}
